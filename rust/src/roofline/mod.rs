//! Roofline analysis (Fig. 4a): arithmetic intensity vs attainable
//! performance for the major kernels in each phase.
//!
//! The paper uses a *qualitative* roofline to argue where resources should
//! go; this module computes the actual numbers from the workload model and
//! device ceilings so the argument can be checked: decode attention sits
//! deep in the memory-bound region, prefill attention far into the
//! compute-bound region, and the decode-stage linears close to their
//! (streaming) roof.

use crate::engines::{AcceleratorDesign, LatencySurface, calib};
use crate::fpga::DeviceConfig;
use crate::memory::MemorySystem;
use crate::model::{
    BatchedDecodeWork, ComponentOps, DecodeStepWork, ModelShape, PhaseWork, PrefillWork,
};

/// Which ceiling binds a kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    Compute,
    Memory,
}

/// One kernel's position on the roofline.
#[derive(Debug, Clone)]
pub struct RooflinePoint {
    pub kernel: String,
    /// MACs per DDR byte.
    pub arithmetic_intensity: f64,
    /// MAC/s the kernel would need to be compute-limited at this AI.
    pub attainable_rate: f64,
    /// MAC/s ceiling of the engine assigned to this kernel.
    pub compute_roof: f64,
    /// B/s ceiling of the memory system for this kernel's streams.
    pub memory_roof_bytes: f64,
    pub bound: Bound,
    /// attainable / compute_roof — how close the kernel runs to its roof.
    pub roof_fraction: f64,
}

/// The device-level roofline: compute ceilings per engine + memory ceiling.
#[derive(Debug, Clone)]
pub struct RooflineModel {
    pub design: AcceleratorDesign,
    pub device: DeviceConfig,
    mem: MemorySystem,
}

/// The ridge point (MACs/byte) where a kernel transitions between regimes
/// for a given compute roof and memory roof.
pub fn ridge_point(compute_roof: f64, memory_roof: f64) -> f64 {
    compute_roof / memory_roof
}

/// Per-kernel ceilings resolved for one shape — the expensive half of
/// [`RooflineModel::analyze`] (engine rates, effective bandwidths, the
/// weight-stream evaluation), cached once so the per-`l` queries the
/// Fig. 4a sweeps and benches issue are pure arithmetic. Built through a
/// [`LatencySurface`], so the numbers are bit-identical to the direct
/// derivation.
#[derive(Debug, Clone)]
pub struct ShapeRoofs {
    shape: ModelShape,
    /// (compute MAC/s, memory B/s) per kernel.
    dec_attn: (f64, f64),
    pre_attn: (f64, f64),
    linear: (f64, f64),
}

fn point(kernel: &str, ops: ComponentOps, compute_roof: f64, memory_roof: f64) -> RooflinePoint {
    let ai = ops.arithmetic_intensity();
    let attainable = compute_roof.min(ai * memory_roof);
    let bound = if ai * memory_roof < compute_roof {
        Bound::Memory
    } else {
        Bound::Compute
    };
    RooflinePoint {
        kernel: kernel.to_string(),
        arithmetic_intensity: ai,
        attainable_rate: attainable,
        compute_roof,
        memory_roof_bytes: memory_roof,
        bound,
        roof_fraction: attainable / compute_roof,
    }
}

impl ShapeRoofs {
    /// The three Fig. 4a panels at context length `l`.
    pub fn analyze_at(&self, l: usize) -> Vec<RooflinePoint> {
        let pre = PrefillWork { shape: self.shape, l };
        let dec = DecodeStepWork { shape: self.shape, l };
        vec![
            point("decode-attention", dec.attention(), self.dec_attn.0, self.dec_attn.1),
            point("prefill-attention", pre.attention(), self.pre_attn.0, self.pre_attn.1),
            point("decode-linear", dec.projection(), self.linear.0, self.linear.1),
            point("prefill-linear", pre.projection(), self.linear.0, self.linear.1),
        ]
    }

    /// The decode kernels' roofline points at batch `b` (per-stream
    /// context `l`): `b` resident streams share ONE pass over the packed
    /// weights, so the decode-linear arithmetic intensity grows ~linearly
    /// with `b` and marches toward the compute ridge, while decode
    /// attention reads `b` independent KV caches and its intensity stays
    /// flat — the roofline argument for multi-stream decode serving (our
    /// extension beyond the paper's batch-1 engine).
    pub fn analyze_batched_at(&self, l: usize, b: usize) -> Vec<RooflinePoint> {
        let work = BatchedDecodeWork { shape: self.shape, l, batch: b.max(1) };
        vec![
            point(
                &format!("decode-attention@b{}", b.max(1)),
                work.attention(),
                self.dec_attn.0,
                self.dec_attn.1,
            ),
            point(
                &format!("decode-linear@b{}", b.max(1)),
                work.projection(),
                self.linear.0,
                self.linear.1,
            ),
        ]
    }

    /// Smallest batch at which the shared weight stream stops binding the
    /// decode linears — the batched decode-linear point crosses the
    /// compute/bandwidth ridge. `None` if no batch up to `max_batch`
    /// crosses (then decode projection stays memory-bound at any
    /// plausible residency).
    pub fn decode_linear_crossover_batch(&self, l: usize, max_batch: usize) -> Option<usize> {
        (1..=max_batch.max(1)).find(|&b| {
            let work = BatchedDecodeWork { shape: self.shape, l, batch: b };
            work.projection().arithmetic_intensity() * self.linear.1 >= self.linear.0
        })
    }
}

impl RooflineModel {
    pub fn new(design: AcceleratorDesign, device: DeviceConfig) -> Self {
        let mem = MemorySystem::for_device(&device);
        Self { design, device, mem }
    }

    /// Resolve the per-kernel ceilings for `shape` once; reuse the result
    /// across context lengths (the hot pattern of the eval sweeps).
    pub fn roofs_for(&self, shape: &ModelShape) -> ShapeRoofs {
        let clock = self.device.clock_hz();
        let surface = LatencySurface::new(&self.design, &self.device, shape, 32);
        // Linear (TLMM): lookup-accumulate roof vs the weight stream.
        let tlmm_roof = self.design.tlmm.n_pe as f64 * 4.0 * clock;
        let weight_bw = shape.ternary_weight_bytes() / surface.weight_stream_time();
        ShapeRoofs {
            shape: *shape,
            // Decode attention: engine MAC roof vs its KV bandwidth.
            dec_attn: (surface.decode_attn_mac_rate(), surface.kv_bandwidth()),
            // Prefill attention: engine MAC roof vs general DDR streaming.
            pre_attn: (
                surface.prefill_attn_mac_rate(),
                self.mem.aggregate_peak * calib::KV_CONTROLLER_EFF,
            ),
            linear: (tlmm_roof, weight_bw),
        }
    }

    /// The three Fig. 4a panels at context length `l` (one-shot form of
    /// [`Self::roofs_for`] + [`ShapeRoofs::analyze_at`]).
    pub fn analyze(&self, shape: &ModelShape, l: usize) -> Vec<RooflinePoint> {
        self.roofs_for(shape).analyze_at(l)
    }

    /// Per-batch decode roofline points (one-shot form of
    /// [`Self::roofs_for`] + [`ShapeRoofs::analyze_batched_at`]): one
    /// `(decode-attention, decode-linear)` pair per entry of `batches`.
    pub fn analyze_batched(
        &self,
        shape: &ModelShape,
        l: usize,
        batches: &[usize],
    ) -> Vec<RooflinePoint> {
        let roofs = self.roofs_for(shape);
        batches
            .iter()
            .flat_map(|&b| roofs.analyze_batched_at(l, b))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::KV260;
    use crate::model::BITNET_0_73B;

    fn model() -> RooflineModel {
        RooflineModel::new(AcceleratorDesign::pd_swap(), KV260.clone())
    }

    fn by_name(points: &[RooflinePoint], name: &str) -> RooflinePoint {
        points.iter().find(|p| p.kernel == name).unwrap().clone()
    }

    #[test]
    fn fig4a_regimes() {
        // The paper's qualitative placement, computed: decode attention
        // memory-bound, prefill attention compute-bound.
        let pts = model().analyze(&BITNET_0_73B, 1024);
        assert_eq!(by_name(&pts, "decode-attention").bound, Bound::Memory);
        assert_eq!(by_name(&pts, "prefill-attention").bound, Bound::Compute);
    }

    #[test]
    fn prefill_ai_dwarfs_decode_ai() {
        let pts = model().analyze(&BITNET_0_73B, 1024);
        let pre = by_name(&pts, "prefill-attention").arithmetic_intensity;
        let dec = by_name(&pts, "decode-attention").arithmetic_intensity;
        assert!(pre > 20.0 * dec, "pre {pre:.2} dec {dec:.2}");
    }

    #[test]
    fn decode_linear_runs_near_its_roof() {
        // §3.3.1: "the decode-stage linear modules ... operate close to
        // their roofline limits" — the streaming roof, not the MAC roof.
        let pts = model().analyze(&BITNET_0_73B, 1024);
        let lin = by_name(&pts, "decode-linear");
        assert_eq!(lin.bound, Bound::Memory);
        // Attainable = AI * weight_bw; actual rate achieved = work/time is
        // the same quantity by construction, so roof_fraction < 1 but the
        // memory roof itself is saturated.
        assert!(lin.attainable_rate > 0.0);
    }

    #[test]
    fn ridge_point_math() {
        assert!((ridge_point(10.0, 2.0) - 5.0).abs() < 1e-12);
        // AI above the ridge -> compute bound.
        let m = model();
        let pts = m.analyze(&BITNET_0_73B, 512);
        for p in pts {
            let ridge = ridge_point(p.compute_roof, p.memory_roof_bytes);
            match p.bound {
                Bound::Compute => assert!(p.arithmetic_intensity >= ridge),
                Bound::Memory => assert!(p.arithmetic_intensity < ridge),
            }
        }
    }

    #[test]
    fn batched_decode_linear_marches_to_the_ridge() {
        // Batching shares the weight stream: decode-linear AI grows
        // ~linearly with B and eventually crosses into the compute-bound
        // regime; decode-attention AI stays flat (per-stream KV).
        let m = model();
        let roofs = m.roofs_for(&BITNET_0_73B);
        let mut last_lin_ai = 0.0;
        for b in [1usize, 2, 4, 8, 16] {
            let pts = roofs.analyze_batched_at(1024, b);
            let lin = by_name(&pts, &format!("decode-linear@b{b}"));
            assert!(lin.arithmetic_intensity > last_lin_ai, "B={b}");
            last_lin_ai = lin.arithmetic_intensity;
            let attn = by_name(&pts, &format!("decode-attention@b{b}"));
            let attn1 = by_name(&roofs.analyze_batched_at(1024, 1), "decode-attention@b1");
            let r = attn.arithmetic_intensity / attn1.arithmetic_intensity;
            assert!((r - 1.0).abs() < 1e-9, "B={b}: attention AI moved ({r})");
        }
        // Batch-1 matches the Fig. 4a single-stream point exactly.
        let single = by_name(&roofs.analyze_at(1024), "decode-linear");
        let b1 = by_name(&roofs.analyze_batched_at(1024, 1), "decode-linear@b1");
        assert_eq!(single.arithmetic_intensity, b1.arithmetic_intensity);
        assert_eq!(single.bound, b1.bound);
    }

    #[test]
    fn decode_linear_crossover_batch_is_consistent() {
        let m = model();
        let roofs = m.roofs_for(&BITNET_0_73B);
        let cross = roofs
            .decode_linear_crossover_batch(1024, 256)
            .expect("shared weight stream must eventually saturate compute");
        assert!(cross > 1, "batch-1 decode linears are memory-bound (the paper's floor)");
        // The verdicts at either side of the crossover agree with the
        // per-point bound classification.
        let below = by_name(
            &roofs.analyze_batched_at(1024, cross - 1),
            &format!("decode-linear@b{}", cross - 1),
        );
        assert_eq!(below.bound, Bound::Memory);
        let at = by_name(
            &roofs.analyze_batched_at(1024, cross),
            &format!("decode-linear@b{cross}"),
        );
        assert_eq!(at.bound, Bound::Compute);
        // No crossover inside a too-small window.
        assert_eq!(roofs.decode_linear_crossover_batch(1024, 1), None);
    }

    #[test]
    fn decode_attention_ai_constant_in_l() {
        // Both MACs and bytes scale linearly with context: AI ~ constant.
        let m = model();
        let a = by_name(&m.analyze(&BITNET_0_73B, 256), "decode-attention")
            .arithmetic_intensity;
        let b = by_name(&m.analyze(&BITNET_0_73B, 2048), "decode-attention")
            .arithmetic_intensity;
        assert!((a - b).abs() / a < 0.05, "{a} vs {b}");
    }
}
