//! Static-region / reconfigurable-partition floorplanning (the DFX pblock
//! split of §3.2.1).
//!
//! At design time the fabric is split into a **static region** (TLMM
//! linear unit, RMSNorm/find-max, controllers, NoC/AXI plumbing — the
//! operators whose dataflow is phase-invariant) and one **reconfigurable
//! partition** (RP) hosting the attention subsystem. The RP can load one
//! **reconfigurable module** (RM) at a time: the prefill attention engine
//! or the decode attention engine. DFX rules modeled here:
//!
//! * the RP pblock must enclose the largest RM in every resource class
//!   (`ResourceVec::max`), plus a placement margin (pblocks cannot be
//!   packed to 100%);
//! * RP pin interface is fixed across RMs (checked by id equality here —
//!   both RMs are generated from the same interface template);
//! * Eq. 2: `static + pblock <= device`, with the routability ceiling
//!   applied on top (§3.3.3's timing-closure feedback).

use super::resources::{DeviceConfig, ResourceVec, ROUTABILITY_CEILING};

/// Placement slack inside a pblock: DFX pblocks route at <= ~80-90% fill,
/// so the partition must be drawn larger than its largest tenant.
pub const PBLOCK_FILL_CEILING: f64 = 0.85;

/// A module that can be loaded into the reconfigurable partition.
#[derive(Debug, Clone, PartialEq)]
pub struct ReconfigurableModule {
    pub name: String,
    /// Fabric cost of the module's engine logic.
    pub resources: ResourceVec,
    /// Interface signature — all RMs of one RP must match (DFX fixes the
    /// partition pins at implementation time).
    pub interface_id: u64,
}

impl ReconfigurableModule {
    pub fn new(name: impl Into<String>, resources: ResourceVec, interface_id: u64) -> Self {
        Self { name: name.into(), resources, interface_id }
    }
}

/// The dynamic pblock: sized at floorplan time, hosts one RM at runtime.
#[derive(Debug, Clone)]
pub struct ReconfigurablePartition {
    /// Fabric area reserved by the pblock (>= largest RM / fill ceiling).
    pub pblock: ResourceVec,
    /// Registered modules (attention-prefill, attention-decode).
    pub modules: Vec<ReconfigurableModule>,
}

impl ReconfigurablePartition {
    /// Floorplan an RP around a set of RMs. Fails if the RMs disagree on
    /// interface (DFX pin compatibility).
    pub fn plan(modules: Vec<ReconfigurableModule>) -> Result<Self, String> {
        if modules.is_empty() {
            return Err("RP needs at least one RM".into());
        }
        let iface = modules[0].interface_id;
        if let Some(bad) = modules.iter().find(|m| m.interface_id != iface) {
            return Err(format!(
                "RM '{}' interface 0x{:x} != partition interface 0x{:x}",
                bad.name, bad.interface_id, iface
            ));
        }
        let largest = modules
            .iter()
            .fold(ResourceVec::ZERO, |acc, m| acc.max(&m.resources));
        let pblock = largest * (1.0 / PBLOCK_FILL_CEILING);
        Ok(Self { pblock, modules })
    }

    pub fn module(&self, name: &str) -> Option<&ReconfigurableModule> {
        self.modules.iter().find(|m| m.name == name)
    }

    /// Can `rm` be hosted (fits the pblock at the fill ceiling)?
    pub fn admits(&self, rm: &ReconfigurableModule) -> bool {
        rm.resources.fits_within(&(self.pblock * PBLOCK_FILL_CEILING))
            && self
                .modules
                .first()
                .map(|m| m.interface_id == rm.interface_id)
                .unwrap_or(true)
    }
}

/// The static region's inventory (Table 2 rows 1-3).
#[derive(Debug, Clone, Default)]
pub struct StaticRegion {
    pub components: Vec<(String, ResourceVec)>,
}

impl StaticRegion {
    pub fn add(&mut self, name: impl Into<String>, r: ResourceVec) {
        self.components.push((name.into(), r));
    }

    pub fn total(&self) -> ResourceVec {
        self.components
            .iter()
            .fold(ResourceVec::ZERO, |acc, (_, r)| acc + *r)
    }
}

/// A complete floorplan: static region + RP on a device, validated.
#[derive(Debug, Clone)]
pub struct RegionPlan {
    pub static_region: StaticRegion,
    pub rp: ReconfigurablePartition,
}

/// Eq. 2 with the routability ceiling, over already-summed resource
/// vectors: `total` (static + pblock) must fit the device, and its LUT/FF
/// congestion must clear [`ROUTABILITY_CEILING`]. Shared by
/// [`RegionPlan::validate`] and the DSE fast kernel
/// ([`crate::dse::DseKernel`]), so the accept/reject rule — and its
/// diagnostics — exist in exactly one place.
pub fn validate_budget(
    static_total: ResourceVec,
    total: ResourceVec,
    device: &DeviceConfig,
) -> Result<PlanReport, String> {
    if !total.fits_within(&device.resources) {
        return Err(format!(
            "floorplan exceeds {}: need {} have {}",
            device.name, total, device.resources
        ));
    }
    // Routability/timing closure is a *logic congestion* phenomenon:
    // the ceiling applies to LUT/FF fill. Hard blocks (BRAM/URAM/DSP)
    // can legitimately run to ~97% — the paper ships at 96% URAM.
    let u = total.utilization(&device.resources);
    let congestion = u.lut.max(u.ff);
    if congestion > ROUTABILITY_CEILING {
        return Err(format!(
            "LUT/FF utilization {:.1}% above routability ceiling {:.0}% — \
             P&R would fail timing (reduce RM parallelism, §3.3.3)",
            congestion * 100.0,
            ROUTABILITY_CEILING * 100.0
        ));
    }
    Ok(PlanReport { static_total, total, peak_utilization: congestion })
}

impl RegionPlan {
    /// Eq. 2 with the routability ceiling: `static + pblock` must fit the
    /// device scaled by [`ROUTABILITY_CEILING`] in its binding class.
    pub fn validate(&self, device: &DeviceConfig) -> Result<PlanReport, String> {
        let static_total = self.static_region.total();
        let total = static_total + self.rp.pblock;
        validate_budget(static_total, total, device)
    }

    /// The paper's "Equivalent Total": static region + *every* RM counted
    /// simultaneously — what a non-DPR design would need (Table 2 last rows).
    pub fn equivalent_total(&self) -> ResourceVec {
        self.rp
            .modules
            .iter()
            .fold(self.static_region.total(), |acc, m| acc + m.resources)
    }
}

/// Result of a successful floorplan validation.
#[derive(Debug, Clone, Copy)]
pub struct PlanReport {
    pub static_total: ResourceVec,
    pub total: ResourceVec,
    pub peak_utilization: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::resources::KV260;

    fn rm(name: &str, lut: f64, iface: u64) -> ReconfigurableModule {
        ReconfigurableModule::new(
            name,
            ResourceVec::new(lut, 1.5 * lut, 20.0, 8.0, lut / 100.0),
            iface,
        )
    }

    #[test]
    fn rp_sized_for_largest_rm() {
        let rp = ReconfigurablePartition::plan(vec![
            rm("prefill", 28_400.0, 1),
            rm("decode", 26_418.0, 1),
        ])
        .unwrap();
        // pblock holds the larger RM with fill margin
        assert!(rp.pblock.lut >= 28_400.0 / PBLOCK_FILL_CEILING - 1e-6);
        assert!(rp.admits(rp.module("prefill").unwrap()));
        assert!(rp.admits(rp.module("decode").unwrap()));
    }

    #[test]
    fn interface_mismatch_rejected() {
        let err = ReconfigurablePartition::plan(vec![rm("a", 100.0, 1), rm("b", 100.0, 2)])
            .unwrap_err();
        assert!(err.contains("interface"));
    }

    #[test]
    fn foreign_rm_too_big_is_rejected() {
        let rp = ReconfigurablePartition::plan(vec![rm("a", 10_000.0, 1)]).unwrap();
        assert!(!rp.admits(&rm("huge", 50_000.0, 1)));
        assert!(!rp.admits(&rm("wrong-iface", 1_000.0, 9)));
    }

    #[test]
    fn plan_validation_enforces_ceiling() {
        let mut sr = StaticRegion::default();
        sr.add("tlmm", ResourceVec::new(42_854.0, 50_752.0, 5.5, 0.0, 320.0));
        sr.add("norm", ResourceVec::new(6_210.0, 11_206.0, 4.0, 4.0, 47.0));
        sr.add("other", ResourceVec::new(21_432.0, 22_402.0, 34.0, 48.0, 5.0));
        let rp = ReconfigurablePartition::plan(vec![
            rm("prefill", 28_400.0, 1),
            rm("decode", 26_418.0, 1),
        ])
        .unwrap();
        let plan = RegionPlan { static_region: sr.clone(), rp };
        let report = plan.validate(&KV260).unwrap();
        assert!(report.peak_utilization < ROUTABILITY_CEILING);

        // Blow up the static region -> validation must fail.
        let mut sr2 = sr;
        sr2.add("bloat", ResourceVec::new(40_000.0, 0.0, 0.0, 0.0, 0.0));
        let rp2 = ReconfigurablePartition::plan(vec![rm("p", 28_400.0, 1)]).unwrap();
        let plan2 = RegionPlan { static_region: sr2, rp: rp2 };
        assert!(plan2.validate(&KV260).is_err());
    }

    #[test]
    fn equivalent_total_counts_both_rms() {
        let mut sr = StaticRegion::default();
        sr.add("s", ResourceVec::new(70_000.0, 0.0, 0.0, 0.0, 0.0));
        let rp = ReconfigurablePartition::plan(vec![
            rm("p", 28_000.0, 1),
            rm("d", 26_000.0, 1),
        ])
        .unwrap();
        let plan = RegionPlan { static_region: sr, rp };
        let eq = plan.equivalent_total();
        assert!((eq.lut - 124_000.0).abs() < 1e-6);
        // Exceeds the chip: the Table 2 ">100%" headline.
        assert!(eq.lut > KV260.resources.lut);
    }
}
