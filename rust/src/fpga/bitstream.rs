//! Partial-bitstream sizing and PCAP configuration timing.
//!
//! On UltraScale+ the configuration frames covering a pblock scale with
//! its fabric footprint, so a partial bitstream's size is well-modeled as
//! the device's full bitstream scaled by the pblock's area fraction (plus
//! per-bitstream command overhead). Configuration time through the PS's
//! PCAP port is `size / pcap_bandwidth` plus a fixed driver/DMA setup cost
//! — the paper measures ~45 ms for its attention RP, which this model
//! reproduces with the KV260 constants.

use super::resources::{DeviceConfig, ResourceVec};

/// Fixed per-reconfiguration software overhead: FPGA manager ioctl, DMA
/// descriptor setup, RP decoupling/re-enable handshakes.
pub const RECONFIG_SETUP_SECONDS: f64 = 2.0e-3;

/// Command/padding overhead factor on partial bitstreams.
pub const BITSTREAM_OVERHEAD: f64 = 1.05;

/// A generated (partial or full) bitstream.
#[derive(Debug, Clone)]
pub struct Bitstream {
    pub name: String,
    pub bytes: f64,
    /// Full-device bitstreams reset the PL; partial ones only the RP.
    pub partial: bool,
}

impl Bitstream {
    /// Partial bitstream for a pblock on `device`.
    ///
    /// The configuration-frame count tracks the *fabric area* of the
    /// pblock; LUT fraction is the best single-number proxy for area on
    /// UltraScale+ (CLB columns dominate the frame address space).
    pub fn partial_for(name: impl Into<String>, pblock: &ResourceVec, device: &DeviceConfig) -> Self {
        let area_fraction = (pblock.lut / device.resources.lut)
            .max(pblock.dsp / device.resources.dsp)
            .max(pblock.bram36 / device.resources.bram36);
        Self {
            name: name.into(),
            bytes: device.full_bitstream_bytes * area_fraction * BITSTREAM_OVERHEAD,
            partial: true,
        }
    }

    pub fn full(device: &DeviceConfig) -> Self {
        Self {
            name: format!("{} (full)", device.name),
            bytes: device.full_bitstream_bytes,
            partial: false,
        }
    }
}

/// The PS-side configuration port model.
#[derive(Debug, Clone)]
pub struct PcapModel {
    pub bytes_per_sec: f64,
    pub setup_seconds: f64,
}

impl PcapModel {
    pub fn for_device(device: &DeviceConfig) -> Self {
        Self {
            bytes_per_sec: device.pcap_bytes_per_sec,
            setup_seconds: RECONFIG_SETUP_SECONDS,
        }
    }

    /// Wall-clock seconds to stream `bs` through PCAP.
    pub fn load_time(&self, bs: &Bitstream) -> f64 {
        self.setup_seconds + bs.bytes / self.bytes_per_sec
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::resources::KV260;

    #[test]
    fn partial_scales_with_area() {
        let small = ResourceVec::new(10_000.0, 20_000.0, 10.0, 4.0, 100.0);
        let big = small * 2.0;
        let bs_small = Bitstream::partial_for("s", &small, &KV260);
        let bs_big = Bitstream::partial_for("b", &big, &KV260);
        assert!((bs_big.bytes / bs_small.bytes - 2.0).abs() < 1e-9);
        assert!(bs_small.partial);
    }

    #[test]
    fn paper_attention_rp_loads_in_about_45ms() {
        // The attention RP from Table 2's dynamic region row: 32,140 LUT /
        // 92,080 FF / 81 BRAM / 10 URAM / 378 DSP.
        let rp = ResourceVec::new(32_140.0, 92_080.0, 81.0, 10.0, 378.0);
        let bs = Bitstream::partial_for("attention-rp", &rp, &KV260);
        let pcap = PcapModel::for_device(&KV260);
        let t = pcap.load_time(&bs);
        // Paper: "approximately 45 ms". BRAM columns are the binding area
        // class for this pblock (81/144 = 56%).
        assert!((0.035..0.055).contains(&t), "got {:.1} ms", t * 1e3);
    }

    #[test]
    fn full_bitstream_slower_than_partial() {
        let rp = ResourceVec::new(32_140.0, 92_080.0, 81.0, 10.0, 378.0);
        let pcap = PcapModel::for_device(&KV260);
        let t_partial = pcap.load_time(&Bitstream::partial_for("p", &rp, &KV260));
        let t_full = pcap.load_time(&Bitstream::full(&KV260));
        assert!(t_full > t_partial);
        assert!((t_full - (KV260.full_bitstream_bytes / KV260.pcap_bytes_per_sec
            + RECONFIG_SETUP_SECONDS))
            .abs()
            < 1e-9);
    }
}
