//! Fabric resource vectors and device budgets.
//!
//! Units follow the paper's Table 2: LUTs, flip-flops, BRAM36 blocks
//! (fractional — a BRAM18 is 0.5), URAM blocks, DSP48 slices. `f64`
//! throughout so fractional BRAM and utilization math stay exact enough.

use std::fmt;
use std::ops::{Add, AddAssign, Mul, Sub};

/// A bundle of the five fabric resource classes.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ResourceVec {
    pub lut: f64,
    pub ff: f64,
    pub bram36: f64,
    pub uram: f64,
    pub dsp: f64,
}

impl ResourceVec {
    pub const ZERO: ResourceVec =
        ResourceVec { lut: 0.0, ff: 0.0, bram36: 0.0, uram: 0.0, dsp: 0.0 };

    pub fn new(lut: f64, ff: f64, bram36: f64, uram: f64, dsp: f64) -> Self {
        Self { lut, ff, bram36, uram, dsp }
    }

    /// Component-wise `self <= other` (the fits-in-budget check).
    pub fn fits_within(&self, budget: &ResourceVec) -> bool {
        self.lut <= budget.lut
            && self.ff <= budget.ff
            && self.bram36 <= budget.bram36
            && self.uram <= budget.uram
            && self.dsp <= budget.dsp
    }

    /// Component-wise maximum — the RP sizing rule: the dynamic region must
    /// hold the *largest* reconfigurable module in every resource class.
    pub fn max(&self, other: &ResourceVec) -> ResourceVec {
        ResourceVec {
            lut: self.lut.max(other.lut),
            ff: self.ff.max(other.ff),
            bram36: self.bram36.max(other.bram36),
            uram: self.uram.max(other.uram),
            dsp: self.dsp.max(other.dsp),
        }
    }

    /// Largest utilization fraction across classes w.r.t. a budget.
    pub fn peak_utilization(&self, budget: &ResourceVec) -> f64 {
        [
            self.lut / budget.lut,
            self.ff / budget.ff,
            self.bram36 / budget.bram36,
            self.uram / budget.uram,
            self.dsp / budget.dsp,
        ]
        .into_iter()
        .fold(0.0, f64::max)
    }

    /// Per-class utilization report against a budget.
    pub fn utilization(&self, budget: &ResourceVec) -> Utilization {
        Utilization {
            lut: self.lut / budget.lut,
            ff: self.ff / budget.ff,
            bram36: self.bram36 / budget.bram36,
            uram: self.uram / budget.uram,
            dsp: self.dsp / budget.dsp,
        }
    }

    pub fn is_nonnegative(&self) -> bool {
        self.lut >= 0.0
            && self.ff >= 0.0
            && self.bram36 >= 0.0
            && self.uram >= 0.0
            && self.dsp >= 0.0
    }
}

impl Add for ResourceVec {
    type Output = ResourceVec;
    fn add(self, o: ResourceVec) -> ResourceVec {
        ResourceVec {
            lut: self.lut + o.lut,
            ff: self.ff + o.ff,
            bram36: self.bram36 + o.bram36,
            uram: self.uram + o.uram,
            dsp: self.dsp + o.dsp,
        }
    }
}

impl AddAssign for ResourceVec {
    fn add_assign(&mut self, o: ResourceVec) {
        *self = *self + o;
    }
}

impl Sub for ResourceVec {
    type Output = ResourceVec;
    fn sub(self, o: ResourceVec) -> ResourceVec {
        ResourceVec {
            lut: self.lut - o.lut,
            ff: self.ff - o.ff,
            bram36: self.bram36 - o.bram36,
            uram: self.uram - o.uram,
            dsp: self.dsp - o.dsp,
        }
    }
}

impl Mul<f64> for ResourceVec {
    type Output = ResourceVec;
    fn mul(self, s: f64) -> ResourceVec {
        ResourceVec {
            lut: self.lut * s,
            ff: self.ff * s,
            bram36: self.bram36 * s,
            uram: self.uram * s,
            dsp: self.dsp * s,
        }
    }
}

impl fmt::Display for ResourceVec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{{lut {:.0}, ff {:.0}, bram {:.1}, uram {:.0}, dsp {:.0}}}",
            self.lut, self.ff, self.bram36, self.uram, self.dsp
        )
    }
}

/// Per-class utilization fractions.
#[derive(Debug, Clone, Copy)]
pub struct Utilization {
    pub lut: f64,
    pub ff: f64,
    pub bram36: f64,
    pub uram: f64,
    pub dsp: f64,
}

impl Utilization {
    pub fn peak(&self) -> f64 {
        [self.lut, self.ff, self.bram36, self.uram, self.dsp]
            .into_iter()
            .fold(0.0, f64::max)
    }
}

/// Above this peak utilization place-and-route is assumed to fail timing —
/// the paper's "iteratively reduce resource utilization in the dynamic
/// partition" loop (§3.3.3) kicks in at this threshold. The paper ships at
/// 87% LUT, so the ceiling sits just above it.
pub const ROUTABILITY_CEILING: f64 = 0.90;

/// A target device (board-level constants; DDR/PCAP live in
/// [`crate::memory`] / [`super::bitstream`]).
#[derive(Debug, Clone)]
pub struct DeviceConfig {
    pub name: &'static str,
    pub resources: ResourceVec,
    /// Fabric clock for the HLS engines (MHz).
    pub clock_mhz: f64,
    /// Full-device configuration bitstream size (bytes); partial bitstream
    /// sizes scale from this by fabric-area fraction.
    pub full_bitstream_bytes: f64,
    /// PCAP configuration throughput (bytes/s).
    pub pcap_bytes_per_sec: f64,
    /// Total DDR capacity (bytes) shared by PS + PL — bounds the KV-cache
    /// pool ([`crate::kvpool`]) after weights and the activation reserve.
    pub ddr_bytes: f64,
    /// Number of `PL<->DDR` high-performance ports.
    pub n_hp_ports: usize,
    /// Peak DDR bandwidth of one HP port (bytes/s).
    pub hp_port_peak: f64,
    /// Aggregate DDR controller ceiling across all ports (bytes/s).
    pub ddr_aggregate_peak: f64,
}

/// AMD Kria KV260 (Zynq UltraScale+ XCK26, the paper's platform).
///
/// Fabric: 117,120 LUT6 / 234,240 FF / 144 BRAM36 / 64 URAM / 1,248 DSP48.
/// 4 GB DDR4-2400 x64 -> 19.2 GB/s controller peak; four 128-bit HP ports.
/// PCAP sustains ~400 MB/s, giving the paper's ~45 ms for the attention RP.
pub const KV260: DeviceConfig = DeviceConfig {
    name: "KV260 (XCK26)",
    resources: ResourceVec {
        lut: 117_120.0,
        ff: 234_240.0,
        bram36: 144.0,
        uram: 64.0,
        dsp: 1_248.0,
    },
    clock_mhz: 250.0,
    full_bitstream_bytes: 25.5e6,
    pcap_bytes_per_sec: 400.0e6,
    ddr_bytes: 4.0 * 1024.0 * 1024.0 * 1024.0,
    n_hp_ports: 4,
    hp_port_peak: 4.8e9,
    ddr_aggregate_peak: 19.2e9,
};

impl DeviceConfig {
    pub fn clock_hz(&self) -> f64 {
        self.clock_mhz * 1e6
    }

    /// Seconds per fabric cycle.
    pub fn cycle_time(&self) -> f64 {
        1.0 / self.clock_hz()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn r(lut: f64) -> ResourceVec {
        ResourceVec::new(lut, 2.0 * lut, lut / 1000.0, lut / 2000.0, lut / 100.0)
    }

    #[test]
    fn arithmetic() {
        let a = r(1000.0);
        let b = r(500.0);
        assert_eq!((a + b).lut, 1500.0);
        assert_eq!((a - b).lut, 500.0);
        assert_eq!((a * 2.0).dsp, 20.0);
        assert!(b.fits_within(&a));
        assert!(!a.fits_within(&b));
        assert!((a - b).is_nonnegative());
        assert!(!(b - a).is_nonnegative());
    }

    #[test]
    fn max_is_componentwise() {
        let a = ResourceVec::new(10.0, 0.0, 5.0, 0.0, 1.0);
        let b = ResourceVec::new(5.0, 2.0, 7.0, 0.0, 0.0);
        let m = a.max(&b);
        assert_eq!(m, ResourceVec::new(10.0, 2.0, 7.0, 0.0, 1.0));
    }

    #[test]
    fn paper_table2_utilization() {
        // Table 2 totals: 102,102 LUT / 176,440 FF / 124.5 BRAM / 62 URAM /
        // 750 DSP on the XCK26 -> 87% / (36%) / 85% / 96% / 60%.
        let total = ResourceVec::new(102_102.0, 176_440.0, 124.5, 62.0, 750.0);
        let u = total.utilization(&KV260.resources);
        assert!((u.lut - 0.87).abs() < 0.005, "lut {:.3}", u.lut);
        assert!((u.bram36 - 0.86).abs() < 0.01, "bram {:.3}", u.bram36);
        assert!((u.uram - 0.97).abs() < 0.01, "uram {:.3}", u.uram);
        assert!((u.dsp - 0.60).abs() < 0.005, "dsp {:.3}", u.dsp);
        // NB: the paper reports FF at 36%; against the XCK26's 234,240 FFs
        // the arithmetic gives 75%. We keep the device constant and flag
        // the discrepancy in EXPERIMENTS.md instead of fudging the budget.
        assert!((u.ff - 0.753).abs() < 0.005, "ff {:.3}", u.ff);
    }

    #[test]
    fn equivalent_total_exceeds_chip() {
        // Table 2 "Equivalent Total": static + BOTH attention RMs counted.
        let equivalent = ResourceVec::new(124_780.0, 136_721.0, 98.5, 62.0, 953.0);
        let u = equivalent.utilization(&KV260.resources);
        assert!(u.lut > 1.0, "the DPR advantage: logic > chip capacity");
    }

    #[test]
    fn kv260_ddr_capacity() {
        // 4 GB on-board DDR; sanity for the KV-pool budget derivation.
        assert_eq!(KV260.ddr_bytes, 4294967296.0);
        assert!(KV260.ddr_bytes > KV260.full_bitstream_bytes);
    }

    #[test]
    fn peak_utilization_picks_binding_class() {
        let x = ResourceVec::new(0.0, 0.0, 0.0, 63.0, 0.0);
        let u = x.peak_utilization(&KV260.resources);
        assert!((u - 63.0 / 64.0).abs() < 1e-9);
    }
}
