//! FPGA substrate simulator — the KV260 stand-in (DESIGN.md §2).
//!
//! The paper deploys on an AMD Kria KV260 (Zynq UltraScale+ XCK26 MPSoC)
//! and evaluates three things this module models:
//!
//! * **fabric resources** ([`resources`]) — LUT/FF/BRAM/URAM/DSP vectors,
//!   the Eq. 2 accounting `r_proj + max(r_pre, r_dec) <= R_total`, and the
//!   utilization arithmetic behind Table 2;
//! * **regions** ([`region`]) — the static region / reconfigurable
//!   partition (RP) split produced by Vivado DFX pblocks, with RP pin
//!   compatibility and the "dynamic region sized for the largest RM" rule;
//! * **partial bitstreams** ([`bitstream`]) — size ∝ RP fabric area, PCAP
//!   streaming time (the 45 ms of Fig. 5), and full-device programming;
//! * **the device** ([`device`]) — a checked composition of the above with
//!   reconfiguration state (which RM is live, is the RP mid-swap).
//!
//! Everything is arithmetic over published device constants — no RTL — but
//! the *checks* are real: any engine configuration the DSE proposes is
//! validated against the same constraints Vivado place-and-route would
//! enforce (capacity, routability-derived utilization ceilings).

pub mod bitstream;
pub mod device;
pub mod region;
pub mod resources;

pub use bitstream::{Bitstream, PcapModel};
pub use device::{FpgaDevice, ReconfigState};
pub use region::{ReconfigurableModule, ReconfigurablePartition, RegionPlan, StaticRegion};
pub use resources::{DeviceConfig, ResourceVec, Utilization, KV260, ROUTABILITY_CEILING};
