//! The simulated FPGA device: floorplan + live reconfiguration state.
//!
//! Composes the resource model, region plan, and PCAP/bitstream timing
//! into the object the coordinator drives: program it, swap RMs, and ask
//! "what is live right now?" — with the same safety rules the real DFX
//! flow enforces (no compute in a partition mid-reconfiguration; the
//! static region keeps running).

use anyhow::{bail, Result};

use super::bitstream::{Bitstream, PcapModel};
use super::region::RegionPlan;
use super::resources::DeviceConfig;

/// What the reconfigurable partition is doing.
#[derive(Debug, Clone, PartialEq)]
pub enum ReconfigState {
    /// Nothing loaded yet (after full programming, before first RM load).
    Empty,
    /// An RM is live and usable.
    Loaded { rm: String },
    /// PCAP is streaming a partial bitstream; the RP is unusable but the
    /// static region keeps running. Carries the target RM and the absolute
    /// simulation time at which the load completes.
    Loading { rm: String, until: f64 },
}

/// A programmed device with one reconfigurable partition.
#[derive(Debug)]
pub struct FpgaDevice {
    pub config: DeviceConfig,
    pub plan: RegionPlan,
    pcap: PcapModel,
    state: ReconfigState,
    /// Precomputed partial bitstream load time (same pblock for all RMs).
    partial_load_seconds: f64,
    /// Telemetry.
    pub reconfig_count: u64,
    pub reconfig_seconds_total: f64,
}

impl FpgaDevice {
    /// "Program" the full bitstream: validates the floorplan against the
    /// device and returns a device with an empty RP.
    pub fn program(config: DeviceConfig, plan: RegionPlan) -> Result<Self> {
        plan.validate(&config).map_err(|e| anyhow::anyhow!(e))?;
        Ok(Self::assemble(config, plan))
    }

    /// [`Self::program`] for floorplans the caller already validated —
    /// e.g. the DSE pass, whose
    /// [`crate::fpga::region::validate_budget`] is the same accept/reject
    /// rule — so sweeps that build many devices per design do not pay the
    /// validation repeatedly. Debug builds still assert validity.
    pub fn program_prevalidated(config: DeviceConfig, plan: RegionPlan) -> Self {
        debug_assert!(
            plan.validate(&config).is_ok(),
            "prevalidated floorplan fails validation"
        );
        Self::assemble(config, plan)
    }

    fn assemble(config: DeviceConfig, plan: RegionPlan) -> Self {
        let pcap = PcapModel::for_device(&config);
        let bs = Bitstream::partial_for("rp", &plan.rp.pblock, &config);
        let partial_load_seconds = pcap.load_time(&bs);
        Self {
            config,
            plan,
            pcap,
            state: ReconfigState::Empty,
            partial_load_seconds,
            reconfig_count: 0,
            reconfig_seconds_total: 0.0,
        }
    }

    pub fn state(&self) -> &ReconfigState {
        &self.state
    }

    /// Seconds to load any of this RP's partial bitstreams.
    pub fn reconfig_latency(&self) -> f64 {
        self.partial_load_seconds
    }

    /// Is `rm` live (loaded and not mid-swap) at simulation time `now`?
    pub fn is_live(&self, rm: &str, now: f64) -> bool {
        match &self.state {
            ReconfigState::Loaded { rm: cur } => cur == rm,
            ReconfigState::Loading { rm: cur, until } => cur == rm && now >= *until,
            ReconfigState::Empty => false,
        }
    }

    /// Settle a completed load (Loading whose deadline passed becomes
    /// Loaded). Call with the current simulation time before queries.
    pub fn settle(&mut self, now: f64) {
        if let ReconfigState::Loading { rm, until } = &self.state {
            if now >= *until {
                self.state = ReconfigState::Loaded { rm: rm.clone() };
            }
        }
    }

    /// Begin a partial reconfiguration to `rm` at simulation time `now`.
    /// Returns the completion time. Fails if the RM is unknown, doesn't
    /// fit the partition, or a swap is already in flight (the PCAP is a
    /// single serial channel).
    pub fn start_reconfig(&mut self, rm: &str, now: f64) -> Result<f64> {
        self.settle(now);
        if let ReconfigState::Loading { rm: cur, until } = &self.state {
            bail!(
                "PCAP busy loading '{}' until t={:.3}s (requested '{}' at t={:.3}s)",
                cur, until, rm, now
            );
        }
        let module = self
            .plan
            .rp
            .module(rm)
            .ok_or_else(|| anyhow::anyhow!("unknown RM '{rm}'"))?;
        if !self.plan.rp.admits(module) {
            bail!("RM '{rm}' does not fit the reconfigurable partition");
        }
        // Loading the already-live RM is a no-op (the controller checks
        // this to avoid paying PCAP time on back-to-back same-phase reqs).
        if matches!(&self.state, ReconfigState::Loaded { rm: cur } if cur == rm) {
            return Ok(now);
        }
        let until = now + self.partial_load_seconds;
        self.state = ReconfigState::Loading { rm: rm.to_string(), until };
        self.reconfig_count += 1;
        self.reconfig_seconds_total += self.partial_load_seconds;
        Ok(until)
    }

    /// Abort the in-flight partial reconfiguration (fault injection: a
    /// bitstream CRC error or PCAP transfer abort detected at the load's
    /// completion point). The RP is left **Empty** — the aborted load
    /// tore the previous RM's configuration frames, so nothing is live
    /// until a fresh `start_reconfig` completes. Deliberately does NOT
    /// settle first: the failure is decided at exactly the moment the
    /// load would have completed, so a `Loading` whose deadline equals
    /// `now` is still the failing load, not a settled success.
    ///
    /// Errors if no load is in flight (a failure needs something to fail).
    pub fn fail_reconfig(&mut self, now: f64) -> Result<()> {
        match &self.state {
            ReconfigState::Loading { .. } => {
                self.state = ReconfigState::Empty;
                Ok(())
            }
            s => bail!("no PCAP load in flight to fail at t={now:.3}s (state {s:?})"),
        }
    }

    /// PCAP bandwidth exposure for diagnostics.
    pub fn pcap(&self) -> &PcapModel {
        &self.pcap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::region::{ReconfigurableModule, ReconfigurablePartition, StaticRegion};
    use crate::fpga::resources::{ResourceVec, KV260};

    fn device() -> FpgaDevice {
        let mut sr = StaticRegion::default();
        sr.add("tlmm", ResourceVec::new(42_854.0, 50_752.0, 5.5, 0.0, 320.0));
        sr.add("norm", ResourceVec::new(6_210.0, 11_206.0, 4.0, 4.0, 47.0));
        sr.add("other", ResourceVec::new(21_432.0, 22_402.0, 34.0, 48.0, 5.0));
        let rp = ReconfigurablePartition::plan(vec![
            ReconfigurableModule::new(
                "attn-prefill",
                ResourceVec::new(28_400.0, 42_053.0, 140.0f64.min(81.0), 8.0, 303.0),
                7,
            ),
            ReconfigurableModule::new(
                "attn-decode",
                ResourceVec::new(26_418.0, 27_236.0, 16.0, 8.0, 278.0),
                7,
            ),
        ])
        .unwrap();
        FpgaDevice::program(KV260.clone(), RegionPlan { static_region: sr, rp }).unwrap()
    }

    #[test]
    fn swap_lifecycle() {
        let mut dev = device();
        assert_eq!(*dev.state(), ReconfigState::Empty);
        assert!(!dev.is_live("attn-prefill", 0.0));

        let done = dev.start_reconfig("attn-prefill", 0.0).unwrap();
        assert!(done > 0.0);
        assert!(!dev.is_live("attn-prefill", done / 2.0), "not live mid-load");
        assert!(dev.is_live("attn-prefill", done));

        // Swapping to decode after completion works and takes the same time.
        dev.settle(done);
        let done2 = dev.start_reconfig("attn-decode", done).unwrap();
        assert!((done2 - done - dev.reconfig_latency()).abs() < 1e-12);
        assert_eq!(dev.reconfig_count, 2);
    }

    #[test]
    fn pcap_is_serial() {
        let mut dev = device();
        let done = dev.start_reconfig("attn-prefill", 0.0).unwrap();
        let err = dev.start_reconfig("attn-decode", done / 2.0).unwrap_err();
        assert!(err.to_string().contains("PCAP busy"));
    }

    #[test]
    fn reload_same_rm_is_free() {
        let mut dev = device();
        let done = dev.start_reconfig("attn-decode", 0.0).unwrap();
        dev.settle(done);
        let t2 = dev.start_reconfig("attn-decode", done).unwrap();
        assert_eq!(t2, done, "same-RM reload must be a no-op");
        assert_eq!(dev.reconfig_count, 1);
    }

    #[test]
    fn unknown_rm_rejected() {
        let mut dev = device();
        assert!(dev.start_reconfig("attn-nope", 0.0).is_err());
    }

    #[test]
    fn fail_reconfig_empties_the_partition_even_at_the_deadline() {
        let mut dev = device();
        let done = dev.start_reconfig("attn-prefill", 0.0).unwrap();
        // Failure decided exactly at the completion point: the load must
        // NOT be treated as settled, and the RP ends Empty.
        dev.fail_reconfig(done).unwrap();
        assert_eq!(*dev.state(), ReconfigState::Empty);
        assert!(!dev.is_live("attn-prefill", done));
        // Nothing in flight anymore: failing again is an error...
        assert!(dev.fail_reconfig(done).is_err());
        // ...and a fresh retry pays full PCAP time from `now`.
        let redo = dev.start_reconfig("attn-prefill", done).unwrap();
        assert!((redo - done - dev.reconfig_latency()).abs() < 1e-12);
        dev.settle(redo);
        assert!(dev.is_live("attn-prefill", redo));
        assert_eq!(dev.reconfig_count, 2, "both attempts hit the PCAP");
    }

    #[test]
    fn reconfig_latency_near_paper_45ms() {
        let dev = device();
        let ms = dev.reconfig_latency() * 1e3;
        assert!((35.0..55.0).contains(&ms), "got {ms:.1} ms");
    }
}
