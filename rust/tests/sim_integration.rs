//! Integration tests over the full simulated stack: DSE -> floorplan ->
//! device -> coordinator -> metrics, plus failure injection.

use pd_swap::coordinator::{
    generate_workload, Policy, Request, SimServer, SimServerConfig, WorkloadConfig,
};
use pd_swap::dse::{explore, DseConfig};
use pd_swap::engines::{
    AcceleratorDesign, AttentionHosting, DecodeAttentionEngine, PhaseModel,
    PrefillAttentionEngine, ScheduleQuality, TlmmEngine,
};
use pd_swap::eval;
use pd_swap::fpga::{FpgaDevice, KV260};
use pd_swap::kvpool::{AdmissionControl, EvictionPolicy, KvPoolConfig};
use pd_swap::model::BITNET_0_73B;
use pd_swap::reconfig::{SwapController, RM_DECODE, RM_PREFILL};

/// The full paper pipeline: run the DSE, program the winning design,
/// serve a workload, and confirm the headline speedup over the static
/// baseline's DSE winner.
#[test]
fn dse_to_serving_pipeline() {
    let mut dpr_cfg = DseConfig::paper_default(
        BITNET_0_73B,
        KV260.clone(),
        AttentionHosting::Reconfigurable,
    );
    // Trim grids for test runtime.
    dpr_cfg.tlmm_grid = vec![320];
    dpr_cfg.prefill_grid = vec![200, 250, 300];
    dpr_cfg.decode_grid = vec![50, 150, 250];
    let mut static_cfg = dpr_cfg.clone();
    static_cfg.hosting = AttentionHosting::StaticBoth;

    let dpr = explore(&dpr_cfg).unwrap();
    let stat = explore(&static_cfg).unwrap();

    let wl = generate_workload(&WorkloadConfig {
        n_requests: 8,
        prompt_len: (64, 1024),
        gen_len: (16, 64),
        ..Default::default()
    });

    let mut cfg_a = SimServerConfig::pd_swap(BITNET_0_73B, KV260.clone());
    cfg_a.design = dpr.best.design.clone();
    let mut a = SimServer::new(cfg_a).unwrap();
    a.run(wl.clone()).unwrap();

    let mut cfg_b = SimServerConfig::tellme_static(BITNET_0_73B, KV260.clone());
    cfg_b.design = stat.best.design.clone();
    let mut b = SimServer::new(cfg_b).unwrap();
    b.run(wl).unwrap();

    assert_eq!(a.metrics.requests_completed.get(), 8);
    assert_eq!(b.metrics.requests_completed.get(), 8);
    assert!(
        a.metrics.e2e.mean() < b.metrics.e2e.mean(),
        "DSE-chosen DPR design must beat DSE-chosen static design: {:.2}s vs {:.2}s",
        a.metrics.e2e.mean(),
        b.metrics.e2e.mean()
    );
}

/// Failure injection: an over-provisioned design must be refused at
/// programming time (P&R gate), not crash the server later.
#[test]
fn oversized_design_is_rejected_at_programming() {
    let mut d = AcceleratorDesign::pd_swap();
    d.prefill_attn = PrefillAttentionEngine { n_dsp: 800, schedule: ScheduleQuality::Tailored };
    let err = SimServer::new(SimServerConfig {
        design: d,
        device: KV260.clone(),
        shape: BITNET_0_73B,
        policy: Policy::SwapPerRequest,
        overlap: true,
        pool: KvPoolConfig::for_device(&BITNET_0_73B, &KV260),
        decode_batch: 1,
    })
    .err()
    .expect("must fail");
    let msg = err.to_string();
    assert!(
        msg.contains("utilization") || msg.contains("exceeds"),
        "unexpected error: {msg}"
    );
}

/// Failure injection: a TLMM engine so large the static region alone
/// overflows — same gate, different component.
#[test]
fn oversized_static_region_rejected() {
    let mut d = AcceleratorDesign::tellme_static();
    d.tlmm = TlmmEngine { n_pe: 1500 };
    assert!(d.program(&KV260).is_err());
}

/// Device-level misuse: decoding against a partition mid-swap is refused
/// by the device (the §3.4 correctness rule at the lowest layer).
#[test]
fn device_refuses_concurrent_swaps() {
    let design = AcceleratorDesign::pd_swap();
    let device: FpgaDevice = design.program(&KV260).unwrap();
    let mut ctl = SwapController::new(device);
    let t_ready = ctl.ensure_prefill(0.0).unwrap();
    // Mid-flight second swap on the serial PCAP must fail.
    assert!(ctl.device.start_reconfig(RM_DECODE, t_ready / 2.0).is_err());
    // After completion it succeeds.
    ctl.device.settle(t_ready);
    assert!(ctl.device.start_reconfig(RM_DECODE, t_ready).is_ok());
    assert!(!ctl.device.is_live(RM_PREFILL, t_ready));
}

/// The eval harnesses all run end-to-end and return structurally sane
/// data (this is what `pd-swap eval all` executes).
#[test]
fn eval_harnesses_run() {
    let t1 = eval::run_table1();
    assert_eq!(t1.len(), 6);
    let (t2_rows, total, eq) = eval::run_table2();
    assert!(t2_rows.len() >= 6);
    assert!(eq.lut > total.lut);
    let f4 = eval::run_fig4a();
    assert_eq!(f4.len(), 3);
    let f5 = eval::run_fig5();
    assert!(f5.iter().any(|r| r.l == 128));
    let f6 = eval::run_fig6(&[64, 2048]);
    assert_eq!(f6.len(), 2);
}

/// Decode throughput from the serving loop agrees with the analytic
/// per-step model (the simulation adds no phantom overheads).
#[test]
fn serving_loop_matches_analytic_model() {
    let shape = BITNET_0_73B;
    let l0 = 256usize;
    let n = 32usize;
    let mut srv = SimServer::new(SimServerConfig::pd_swap(shape, KV260.clone())).unwrap();
    srv.run(vec![Request::synthetic(0, l0, n, 0.0)]).unwrap();

    let model = PhaseModel::new(AcceleratorDesign::pd_swap(), KV260.clone());
    let analytic = model.decode_span(&shape, l0, n) / n as f64;
    let measured = srv.metrics.tpot.mean();
    let rel = (measured / analytic - 1.0).abs();
    assert!(
        rel < 0.02,
        "serving tpot {measured:.4} vs analytic {analytic:.4} ({rel:.3} rel)"
    );
}

/// The KV-pool acceptance scenario: a workload whose aggregate worst-case
/// KV footprint exceeds the modeled DDR KV budget is served without
/// panicking — requests are admitted/evicted per policy, the page
/// accounting balances at drain, and `ServerMetrics` carries the pool
/// high-water mark, eviction count, and recompute overhead.
#[test]
fn over_budget_workload_is_served_with_pool_accounting() {
    let shape = BITNET_0_73B;
    // Shrink the pool to 96 pages (3072 KV tokens) so ~16 long requests
    // oversubscribe it several times over.
    let base_pool = KvPoolConfig::for_device(&shape, &KV260).with_total_pages(96);
    let wl: Vec<Request> = (0..16)
        .map(|i| Request::synthetic(i, 512, 96, i as f64 * 0.1))
        .collect();
    let aggregate_worst: usize = wl
        .iter()
        .map(|r| base_pool.worst_case_pages(r.prompt_len, r.max_new_tokens))
        .sum();
    assert!(
        aggregate_worst > 2 * base_pool.total_pages,
        "workload must oversubscribe the budget ({aggregate_worst} vs {})",
        base_pool.total_pages
    );

    for (admission, eviction) in [
        (AdmissionControl::WorstCase, EvictionPolicy::KeepResident),
        (AdmissionControl::Optimistic, EvictionPolicy::EvictAndRecompute),
        (AdmissionControl::Optimistic, EvictionPolicy::KeepResident),
    ] {
        let mut cfg = SimServerConfig::pd_swap(shape, KV260.clone());
        cfg.policy = Policy::BatchedPhases { max_batch: 16 };
        cfg.pool = base_pool.clone().with_policies(admission, eviction);
        let mut s = SimServer::new(cfg).unwrap();
        s.run(wl.clone()).unwrap();

        assert_eq!(
            s.metrics.requests_completed.get(),
            16,
            "{admission:?}/{eviction:?}: every request finishes"
        );
        let pool = s.pool();
        pool.check_invariants()
            .unwrap_or_else(|e| panic!("{admission:?}/{eviction:?}: {e}"));
        assert_eq!(pool.resident_count(), 0, "pool balances at drain");
        assert_eq!(pool.used_pages(), 0);
        assert_eq!(pool.stats.completed, 16);
        // The metrics bundle carries the pool telemetry.
        assert!(s.metrics.kv_pool_high_water.get() > 0);
        assert!(s.metrics.kv_pool_high_water.get() <= 96);
        assert_eq!(s.metrics.kv_evictions.get(), pool.stats.evicted);
        if eviction == EvictionPolicy::EvictAndRecompute {
            assert_eq!(
                s.metrics.recompute_overhead.count(),
                pool.stats.evicted,
                "every eviction re-prefills exactly once"
            );
        } else {
            assert_eq!(s.metrics.kv_evictions.get(), 0);
        }
    }
}

/// Ablation consistency: disabling each PD-Swap ingredient degrades the
/// metric it owns and only that one.
#[test]
fn ablation_matrix() {
    let shape = BITNET_0_73B;
    let wl: Vec<Request> = (0..4)
        .map(|i| Request::synthetic(i, 1024, 32, i as f64 * 0.5))
        .collect();

    let run = |cfg: SimServerConfig| {
        let mut s = SimServer::new(cfg).unwrap();
        s.run(wl.clone()).unwrap();
        (
            s.metrics.tpot.mean(),
            s.metrics.reconfig_exposed.mean(),
        )
    };

    let full = run(SimServerConfig::pd_swap(shape, KV260.clone()));

    // No port remap -> slower decode, overlap untouched.
    let mut no_ports = SimServerConfig::pd_swap(shape, KV260.clone());
    no_ports.design.decode_attn = DecodeAttentionEngine {
        kv_optimized_ports: false,
        ..no_ports.design.decode_attn
    };
    let np = run(no_ports);
    assert!(np.0 > full.0 * 1.3, "port remap ablation: {:.4} vs {:.4}", np.0, full.0);

    // No overlap -> more exposed reconfig latency, same decode speed.
    let mut no_overlap = SimServerConfig::pd_swap(shape, KV260.clone());
    no_overlap.overlap = false;
    let nov = run(no_overlap);
    assert!(nov.1 > full.1, "overlap ablation: {:.4} vs {:.4}", nov.1, full.1);
    assert!((nov.0 / full.0 - 1.0).abs() < 0.01, "decode speed should be unchanged");
}

/// End-to-end decode-batch codesign: the joint sweep crossed with the
/// multi-stream decode axis produces a deterministic per-batch winner
/// table and a flip verdict for every trace — the machine-readable form
/// `pd-swap codesign --decode-batch 1,4` publishes as a CI artifact.
#[test]
fn codesign_decode_batch_axis_end_to_end() {
    use pd_swap::dse::{run_codesign, CodesignConfig, PoolVariant, TracePreset};
    use pd_swap::kvpool::PAGE_TOKENS_DEFAULT;

    let mut sweep = CodesignConfig::paper_default(BITNET_0_73B, KV260.clone());
    sweep.dse.tlmm_grid = vec![320];
    sweep.dse.prefill_grid = vec![250, 300];
    sweep.dse.decode_grid = vec![150, 250];
    sweep.traces = vec![
        TracePreset::by_name("mixed", 6, 0.05, 2048, 7).unwrap(),
        TracePreset::by_name("bursty", 6, 0.05, 2048, 7).unwrap(),
    ];
    sweep.decode_batches = vec![1, 4];
    // Cross the KV-pool axis in too: the default pool plus an
    // optimistic/evicting variant at a larger page size.
    sweep.pools = vec![
        PoolVariant::paper_default(),
        PoolVariant {
            admission: AdmissionControl::Optimistic,
            eviction: EvictionPolicy::EvictAndRecompute,
            page_tokens: 2 * PAGE_TOKENS_DEFAULT,
        },
    ];
    let report = run_codesign(&sweep).unwrap();
    assert_eq!(
        report.sims_run,
        report.designs_swept * sweep.policies.len() * sweep.traces.len() * 2 * 2
    );

    // Every trace gets a winner per batch and a flip verdict.
    let flips = report.batch_flips();
    assert_eq!(flips.len(), 2);
    for f in &flips {
        assert_eq!(f.winners.len(), 2, "{}: one winner per swept batch", f.trace);
        let expect = f.winners[0].1 != f.winners[1].1 || f.winners[0].2 != f.winners[1].2;
        assert_eq!(f.flips, expect, "{}", f.trace);
    }

    // The JSON artifact carries the batch axis and the verdicts.
    let v = report.to_json(5);
    let batches = v.get("decode_batches").unwrap().as_arr().unwrap();
    assert_eq!(batches.len(), 2);
    assert_eq!(v.get("decode_batch_flips").unwrap().as_arr().unwrap().len(), 2);
    let mixed = v.get("traces").unwrap().get("mixed").unwrap();
    let by_batch = mixed.get("winner_by_decode_batch").unwrap();
    assert!(by_batch.get("b1").is_some() && by_batch.get("b4").is_some());
    let by_pool = mixed.get("winner_by_pool").unwrap();
    for label in &report.pools {
        assert!(by_pool.get(label).is_some(), "missing pool winner '{label}'");
    }
    assert_eq!(v.get("pool_flips").unwrap().as_arr().unwrap().len(), 2);
    assert!(
        mixed
            .get("winner")
            .unwrap()
            .get("decode_batch")
            .unwrap()
            .as_f64()
            .is_some()
    );

    // Determinism across runs (fresh config, different thread count).
    let mut again = CodesignConfig::paper_default(BITNET_0_73B, KV260.clone());
    again.dse.tlmm_grid = vec![320];
    again.dse.prefill_grid = vec![250, 300];
    again.dse.decode_grid = vec![150, 250];
    again.traces = vec![
        TracePreset::by_name("mixed", 6, 0.05, 2048, 7).unwrap(),
        TracePreset::by_name("bursty", 6, 0.05, 2048, 7).unwrap(),
    ];
    again.decode_batches = vec![1, 4];
    again.pools = sweep.pools.clone();
    again.threads = 3;
    let b = run_codesign(&again).unwrap();
    for (fa, fb) in flips.iter().zip(b.batch_flips()) {
        assert_eq!(fa.trace, fb.trace);
        assert_eq!(fa.flips, fb.flips);
        assert_eq!(fa.winners, fb.winners);
    }
}
