//! Property-based invariant tests over the coordinator, DSE, memory, and
//! reconfiguration substrates (driven by the in-crate `util::prop`
//! mini-framework; proptest is unavailable offline).

use pd_swap::coordinator::{
    requests_from_stream, requests_from_trace, semantic_fingerprint, EventServer,
    EventServerConfig, Policy, Request, Scheduler, SimServer, SimServerConfig,
};
use pd_swap::dse::{evaluate_grid_point, explore_threads, DseConfig, DseKernel};
use pd_swap::engines::{AcceleratorDesign, AttentionHosting, LatencySurface, PhaseModel};
use pd_swap::faults::{FaultPlan, FaultSpec};
use pd_swap::fpga::{ResourceVec, KV260};
use pd_swap::kvpool::{AdmissionControl, AdmissionDecision, EvictionPolicy, KvPool, KvPoolConfig};
use pd_swap::memory::{AxiBurst, MemorySystem, PortAssignment, PortMapping, Stream};
use pd_swap::model::{TraceSpec, BITNET_0_73B};
use pd_swap::reconfig::{OverlapScheduler, SwapPolicy, SwapRetryPolicy};
use pd_swap::util::par::par_map;
use pd_swap::util::prop::{check, Config};
use pd_swap::util::rng::Rng;

fn cfg(cases: usize) -> Config {
    Config { cases, seed: 0xC0FFEE, max_size: 48 }
}

/// Eq. 2 is never violated by any design the DSE marks feasible.
#[test]
fn prop_dse_feasible_implies_eq2() {
    let dse = DseConfig::paper_default(
        BITNET_0_73B,
        KV260.clone(),
        AttentionHosting::Reconfigurable,
    );
    check(
        cfg(128),
        |rng, _| {
            (
                *rng.choose(&[160usize, 240, 320, 400]),
                rng.range(2, 26) * 25,  // prefill DSP
                rng.range(1, 26) * 25,  // decode DSP
            )
        },
        |&(tlmm, pre, dec)| {
            let p = evaluate_grid_point(&dse, tlmm, pre, dec);
            if !p.feasible {
                return Ok(()); // infeasible points carry a reason, fine
            }
            let plan = p.design.region_plan().map_err(|e| e.to_string())?;
            let total = plan.static_region.total() + plan.rp.pblock;
            if total.fits_within(&KV260.resources) {
                Ok(())
            } else {
                Err(format!("feasible design violates Eq.2: {total}"))
            }
        },
    );
}

/// The latency surface is a cached restatement of the phase model, not an
/// approximation: across the paper's DSE grid ranges, both hosting modes,
/// every context breakpoint (the prefill weight-stream knee, the paged
/// AXI-burst knee, the extremes), and arbitrary page sizes, the
/// surface-cached latencies must equal the uncached [`PhaseModel`]
/// results within 1e-9 relative (they are in fact bit-identical), and the
/// DSE fast kernel must agree with the uncached `evaluate` verdicts.
#[test]
fn prop_surface_matches_phase_model() {
    fn rel(a: f64, b: f64) -> f64 {
        let scale = a.abs().max(b.abs());
        if scale == 0.0 {
            0.0
        } else {
            (a - b).abs() / scale
        }
    }
    check(
        cfg(48),
        |rng, _| {
            (
                rng.chance(0.5),
                *rng.choose(&[160usize, 240, 320, 400]),
                rng.range(2, 18) * 25,  // prefill DSP (paper grid range)
                rng.range(1, 12) * 25,  // decode DSP
                rng.range(1, BITNET_0_73B.max_seq),
                *rng.choose(&[1usize, 2, 4, 8, 16, 32, 64, 128]),
            )
        },
        |&(dpr, tlmm, pre, dec, l_rand, page)| {
            let hosting = if dpr {
                AttentionHosting::Reconfigurable
            } else {
                AttentionHosting::StaticBoth
            };
            let dse = DseConfig::paper_default(BITNET_0_73B, KV260.clone(), hosting);
            // DSE kernel vs uncached evaluate: same verdict, same numbers.
            let slow = evaluate_grid_point(&dse, tlmm, pre, dec);
            let fast = DseKernel::new(&dse).evaluate(tlmm, pre, dec);
            if fast.feasible != slow.feasible || fast.reject_reason != slow.reject_reason {
                return Err(format!(
                    "kernel verdict diverged at ({tlmm},{pre},{dec}): {:?} vs {:?}",
                    fast.reject_reason, slow.reject_reason
                ));
            }
            if fast.feasible && rel(fast.objective, slow.objective) > 1e-9 {
                return Err(format!(
                    "kernel objective diverged: {} vs {}",
                    fast.objective, slow.objective
                ));
            }
            // Latency surface vs phase model at the breakpoints + a random
            // context (valid for infeasible designs too — latency math
            // does not need a floorplan).
            let design = slow.design.clone();
            let model = PhaseModel::new(design.clone(), KV260.clone());
            let surface = LatencySurface::new(&design, &KV260, &BITNET_0_73B, 32);
            let knee = surface.prefill_projection_breakpoint().round() as usize;
            let max_seq = BITNET_0_73B.max_seq;
            let contexts = [
                1,
                2,
                7,
                8, // paged-burst knee at head_dim 64 / fp16
                knee.saturating_sub(1).clamp(1, max_seq),
                knee.clamp(1, max_seq),
                (knee + 1).clamp(1, max_seq),
                l_rand,
                max_seq - 1,
                max_seq,
            ];
            for l in contexts {
                let e = rel(surface.prefill(l).total, model.prefill(&BITNET_0_73B, l).total);
                if e > 1e-9 {
                    return Err(format!("prefill diverged at L={l}: {e:.3e}"));
                }
                let e = rel(
                    surface.decode_step(l).total,
                    model.decode_step(&BITNET_0_73B, l).total,
                );
                if e > 1e-9 {
                    return Err(format!("decode diverged at L={l}: {e:.3e}"));
                }
                let e = rel(
                    surface.decode_step_paged(l, page).total,
                    model.decode_step_paged(&BITNET_0_73B, l, page).total,
                );
                if e > 1e-9 {
                    return Err(format!("paged decode diverged at L={l} page={page}: {e:.3e}"));
                }
                let e = rel(
                    surface.prefill_tail(l),
                    model.prefill_tail_after_last_attention(&BITNET_0_73B, l),
                );
                if e > 1e-9 {
                    return Err(format!("prefill tail diverged at L={l}: {e:.3e}"));
                }
                // Batched decode: per-B closed forms over the same grid,
                // for B in {1, 2, 4, 8} (uniform and mixed contexts).
                for b in [1usize, 2, 4, 8] {
                    let ctxs = vec![l; b];
                    let e = rel(
                        surface.decode_step_batched(&ctxs).total,
                        model.decode_step_batched(&BITNET_0_73B, &ctxs).total,
                    );
                    if e > 1e-9 {
                        return Err(format!("batched decode diverged at L={l} B={b}: {e:.3e}"));
                    }
                    let e = rel(
                        surface.decode_step_batched_paged(&ctxs, page).total,
                        model
                            .decode_step_batched_paged(&BITNET_0_73B, &ctxs, page)
                            .total,
                    );
                    if e > 1e-9 {
                        return Err(format!(
                            "paged batched decode diverged at L={l} B={b} page={page}: {e:.3e}"
                        ));
                    }
                }
            }
            // Mixed per-stream contexts across the breakpoints.
            let mixed = [1usize, l_rand, max_seq.min(knee.max(1)), max_seq];
            let e = rel(
                surface.decode_step_batched_paged(&mixed, page).total,
                model.decode_step_batched_paged(&BITNET_0_73B, &mixed, page).total,
            );
            if e > 1e-9 {
                return Err(format!("mixed-context batched decode diverged: {e:.3e}"));
            }
            Ok(())
        },
    );
}

/// Batch-1 of the batched decode step is *bit-identical* to the
/// single-stream decode step — on both the phase model and the surface,
/// monolithic and paged, across random designs, contexts, and page
/// sizes. This is the anchor that lets the batch-1 serving path (the
/// paper's figures) trust the batched kernel.
#[test]
fn prop_batch1_decode_is_bitwise_single_step() {
    check(
        cfg(64),
        |rng, _| {
            (
                rng.chance(0.5),
                *rng.choose(&[160usize, 240, 320, 400]),
                rng.range(2, 18) * 25,
                rng.range(1, 12) * 25,
                rng.range(1, BITNET_0_73B.max_seq),
                *rng.choose(&[1usize, 2, 8, 32, 128]),
            )
        },
        |&(dpr, tlmm, pre, dec, l, page)| {
            let hosting = if dpr {
                AttentionHosting::Reconfigurable
            } else {
                AttentionHosting::StaticBoth
            };
            let dse = DseConfig::paper_default(BITNET_0_73B, KV260.clone(), hosting);
            let design = evaluate_grid_point(&dse, tlmm, pre, dec).design;
            let model = PhaseModel::new(design.clone(), KV260.clone());
            let surface = LatencySurface::new(&design, &KV260, &BITNET_0_73B, 32);
            let a = model.decode_step_batched(&BITNET_0_73B, &[l]).total.to_bits();
            let b = model.decode_step(&BITNET_0_73B, l).total.to_bits();
            if a != b {
                return Err(format!("model batch-1 differs from decode_step at L={l}"));
            }
            let a = model
                .decode_step_batched_paged(&BITNET_0_73B, &[l], page)
                .total
                .to_bits();
            let b = model.decode_step_paged(&BITNET_0_73B, l, page).total.to_bits();
            if a != b {
                return Err(format!(
                    "model batch-1 differs from decode_step_paged at L={l} page={page}"
                ));
            }
            let a = surface.decode_step_batched(&[l]).total.to_bits();
            let b = surface.decode_step(l).total.to_bits();
            if a != b {
                return Err(format!("surface batch-1 differs from decode_step at L={l}"));
            }
            let a = surface.decode_step_batched_paged(&[l], page).total.to_bits();
            let b = surface.decode_step_paged(l, page).total.to_bits();
            if a != b {
                return Err(format!(
                    "surface batch-1 differs from decode_step_paged at L={l} page={page}"
                ));
            }
            Ok(())
        },
    );
}

/// Batched-decode structure: the total is monotone in batch size, the
/// per-token latency never grows with B (the shared weight stream can
/// only help), and the projection term is exactly
/// `max(B / tps, T_weights)` with its knee at
/// `LatencySurface::decode_batch_breakpoint`.
#[test]
fn prop_batched_decode_monotone_and_kneed() {
    let surface = LatencySurface::new(
        &AcceleratorDesign::pd_swap(),
        &KV260,
        &BITNET_0_73B,
        32,
    );
    check(
        cfg(128),
        |rng, _| (rng.range(1, BITNET_0_73B.max_seq), rng.range(1, 24)),
        |&(l, b)| {
            let step_b = surface.decode_step_batched_paged(&vec![l; b], 32);
            let step_b1 = surface.decode_step_batched_paged(&vec![l; b + 1], 32);
            if step_b1.total <= step_b.total {
                return Err(format!("total not monotone at L={l} B={b}"));
            }
            if step_b1.per_token() > step_b.per_token() + 1e-12 {
                return Err(format!("per-token grew with batch at L={l} B={b}"));
            }
            let knee = surface.decode_batch_breakpoint();
            let expect_stream_bound = (b as f64) < knee;
            let stream_bound = step_b.projection == surface.weight_stream_time();
            if expect_stream_bound != stream_bound && (b as f64 - knee).abs() > 1e-6 {
                return Err(format!(
                    "projection knee misplaced: B={b} knee={knee:.2} proj={} T_w={}",
                    step_b.projection,
                    surface.weight_stream_time()
                ));
            }
            Ok(())
        },
    );
}

/// Parallel `explore` is a pure evaluation fan-out over a serial
/// reduction: for any grid and any worker count it must return the
/// *identical* `DseResult` (winner, counts, top-k names and bit-exact
/// objectives) as the single-threaded path.
#[test]
fn prop_parallel_explore_matches_serial() {
    check(
        cfg(24),
        |rng, _| {
            let tlmm = vec![*rng.choose(&[160usize, 240, 320, 400])];
            let pre: Vec<usize> =
                (0..rng.range(2, 4)).map(|_| rng.range(2, 18) * 25).collect();
            let dec: Vec<usize> =
                (0..rng.range(2, 4)).map(|_| rng.range(1, 12) * 25).collect();
            let threads = rng.range(2, 8);
            let dpr = rng.chance(0.7);
            (tlmm, pre, dec, threads, dpr)
        },
        |(tlmm, pre, dec, threads, dpr)| {
            let hosting = if *dpr {
                AttentionHosting::Reconfigurable
            } else {
                AttentionHosting::StaticBoth
            };
            let mut dse = DseConfig::paper_default(BITNET_0_73B, KV260.clone(), hosting);
            dse.tlmm_grid = tlmm.clone();
            dse.prefill_grid = pre.clone();
            dse.decode_grid = dec.clone();
            match (explore_threads(&dse, 1), explore_threads(&dse, *threads)) {
                (Err(_), Err(_)) => Ok(()), // both agree: nothing feasible
                (Ok(s), Ok(p)) => {
                    if s.explored != p.explored || s.feasible != p.feasible {
                        return Err("counts diverged".into());
                    }
                    if s.best.design.name != p.best.design.name
                        || s.best.objective.to_bits() != p.best.objective.to_bits()
                    {
                        return Err(format!(
                            "winner diverged: {} vs {}",
                            s.best.design.name, p.best.design.name
                        ));
                    }
                    if s.top.len() != p.top.len() {
                        return Err("top-k length diverged".into());
                    }
                    for (a, b) in s.top.iter().zip(&p.top) {
                        if a.design.name != b.design.name
                            || a.objective.to_bits() != b.objective.to_bits()
                        {
                            return Err(format!(
                                "top-k order diverged: {} vs {}",
                                a.design.name, b.design.name
                            ));
                        }
                    }
                    Ok(())
                }
                _ => Err("serial and parallel disagreed on feasibility".into()),
            }
        },
    );
}

/// Port arbitration: transfer time never beats the aggregate-bandwidth
/// floor, and striping a stream never makes it slower.
#[test]
fn prop_memory_arbitration_bounds() {
    let mem = MemorySystem::for_device(&KV260);
    check(
        cfg(256),
        |rng, size| {
            let streams = [Stream::K, Stream::V, Stream::Q, Stream::O, Stream::Weights];
            (0..rng.range(1, 4))
                .map(|_| PortAssignment {
                    stream: *rng.choose(&streams),
                    bytes: (rng.f64() * 1e8 * size as f64).max(1.0),
                    burst: AxiBurst { beats: rng.range(1, 256) },
                })
                .collect::<Vec<_>>()
        },
        |demands| {
            let base = PortMapping::qkvo_baseline(4);
            let opt = PortMapping::decode_kv_optimized(4);
            let total: f64 = demands.iter().map(|d| d.bytes).sum();
            for mapping in [&base, &opt] {
                let t = mem.transfer_time(mapping, demands);
                if !t.is_finite() || t < 0.0 {
                    return Err(format!("non-finite transfer time {t}"));
                }
                let floor = total / mem.aggregate_peak;
                if t + 1e-12 < floor {
                    return Err(format!(
                        "time {t} beats the controller floor {floor} under {}",
                        mapping.name
                    ));
                }
            }
            // KV-heavy demand must not be slower under the 2K+2V remap.
            let kv_only: Vec<_> = demands
                .iter()
                .filter(|d| matches!(d.stream, Stream::K | Stream::V))
                .cloned()
                .collect();
            if !kv_only.is_empty() {
                let tb = mem.transfer_time(&base, &kv_only);
                let to = mem.transfer_time(&opt, &kv_only);
                if to > tb * 1.001 {
                    return Err(format!("remap slowed KV: {to} > {tb}"));
                }
            }
            Ok(())
        },
    );
}

/// Overlap arithmetic: exposed latency is within [0, reconfig] and
/// overlapped decode-ready never exceeds sequential decode-ready.
#[test]
fn prop_overlap_bounds() {
    let design = AcceleratorDesign::pd_swap();
    let device = design.program(&KV260).unwrap();
    let lat = device.reconfig_latency();
    let sched = OverlapScheduler::new(PhaseModel::new(design, KV260.clone()), lat);
    check(
        cfg(256),
        |rng, _| rng.range(1, BITNET_0_73B.max_seq),
        |&l| {
            let o = sched.overlapped(&BITNET_0_73B, l);
            let s = sched.sequential(&BITNET_0_73B, l);
            if o.exposed < -1e-12 {
                return Err(format!("negative exposed latency {}", o.exposed));
            }
            if o.exposed > o.reconfig + 1e-12 {
                return Err("exposed exceeds the full reconfig cost".into());
            }
            if o.decode_ready > s.decode_ready + 1e-12 {
                return Err("overlap made things worse".into());
            }
            if !(0.0..=1.0 + 1e-12).contains(&o.hidden_fraction) {
                return Err(format!("hidden fraction {} out of range", o.hidden_fraction));
            }
            Ok(())
        },
    );
}

/// Scheduler conservation: every admitted request is dispatched exactly
/// once, in arrival-compatible order, under any policy.
#[test]
fn prop_scheduler_conservation() {
    check(
        cfg(256),
        |rng, size| {
            let n = rng.range(1, size.max(2));
            let policy = if rng.chance(0.5) {
                Policy::SwapPerRequest
            } else {
                Policy::BatchedPhases { max_batch: rng.range(1, 8) }
            };
            let mut t = 0.0;
            let reqs: Vec<Request> = (0..n)
                .map(|i| {
                    t += rng.f64();
                    Request::synthetic(i as u64, rng.range(1, 512), rng.range(1, 64), t)
                })
                .collect();
            (policy, reqs)
        },
        |(policy, reqs)| {
            let mut s = Scheduler::new(*policy);
            for r in reqs.clone() {
                s.admit(r);
            }
            let mut seen = Vec::new();
            let mut guard = 0;
            while !s.is_empty() {
                guard += 1;
                if guard > 10_000 {
                    return Err("scheduler livelock".into());
                }
                let now = s.next_arrival().unwrap_or(f64::MAX);
                for r in s.next_batch(now) {
                    seen.push(r.id);
                }
            }
            if seen.len() != reqs.len() {
                return Err(format!("lost/duplicated: {} of {}", seen.len(), reqs.len()));
            }
            let mut sorted = seen.clone();
            sorted.sort_unstable();
            sorted.dedup();
            if sorted.len() != seen.len() {
                return Err("duplicate dispatch".into());
            }
            if s.admitted != s.dispatched {
                return Err("counter mismatch".into());
            }
            Ok(())
        },
    );
}

/// End-to-end simulation sanity under random workloads: every request
/// completes, KV capacity is respected, the clock only moves forward, and
/// decode throughput stays within physical bounds.
#[test]
fn prop_sim_server_sanity() {
    check(
        cfg(48),
        |rng, size| {
            let n = rng.range(1, (size / 8).max(2));
            let mut t = 0.0;
            (0..n)
                .map(|i| {
                    t += rng.f64() * 2.0;
                    Request::synthetic(
                        i as u64,
                        rng.range(1, 1024),
                        rng.range(1, 64),
                        t,
                    )
                })
                .collect::<Vec<_>>()
        },
        |reqs| {
            let mut srv = SimServer::new(SimServerConfig::pd_swap(
                BITNET_0_73B,
                KV260.clone(),
            ))
            .map_err(|e| e.to_string())?;
            srv.run(reqs.clone()).map_err(|e| e.to_string())?;
            if srv.metrics.requests_completed.get() != reqs.len() as u64 {
                return Err("request lost".into());
            }
            for o in &srv.outcomes {
                if o.ttft < 0.0 || o.e2e < o.ttft - 1e-9 {
                    return Err(format!("latency accounting broken: {o:?}"));
                }
            }
            // Decode throughput can never exceed the projection floor.
            let tp = srv.metrics.decode_throughput();
            if tp > 35.0 {
                return Err(format!("impossible decode throughput {tp}"));
            }
            Ok(())
        },
    );
}

/// KV-pool conservation under arbitrary admit/grow/evict/complete
/// interleavings: pages are conserved (`free + reserved == total`), no
/// request exceeds its reservation or token cap, and
/// `admitted − evicted − completed == resident` after every operation.
#[test]
fn prop_kvpool_invariants() {
    #[derive(Debug, Clone, Copy)]
    enum Op {
        Admit { prompt: usize, gen: usize },
        Grow { victim_slot: usize, tokens: usize },
        Complete { victim_slot: usize },
        Evict { victim_slot: usize },
        Touch { victim_slot: usize },
    }

    check(
        cfg(192),
        |rng, size| {
            let total_pages = rng.range(1, 64);
            let admission = if rng.chance(0.5) {
                AdmissionControl::WorstCase
            } else {
                AdmissionControl::Optimistic
            };
            let eviction = if rng.chance(0.5) {
                EvictionPolicy::EvictAndRecompute
            } else {
                EvictionPolicy::KeepResident
            };
            let n_ops = rng.range(1, (4 * size).max(2));
            let ops: Vec<Op> = (0..n_ops)
                .map(|_| match rng.below(8) {
                    0 | 1 | 2 => Op::Admit {
                        prompt: rng.range(1, 1024),
                        gen: rng.range(1, 128),
                    },
                    3 | 4 => Op::Grow {
                        victim_slot: rng.below(16),
                        tokens: rng.range(1, 64),
                    },
                    5 => Op::Complete { victim_slot: rng.below(16) },
                    6 => Op::Evict { victim_slot: rng.below(16) },
                    _ => Op::Touch { victim_slot: rng.below(16) },
                })
                .collect();
            (total_pages, admission, eviction, ops)
        },
        |(total_pages, admission, eviction, ops)| {
            let pool_cfg = KvPoolConfig::for_device(&BITNET_0_73B, &KV260)
                .with_total_pages(*total_pages)
                .with_policies(*admission, *eviction);
            let mut pool = KvPool::new(pool_cfg);
            let mut next_id = 0u64;
            // (id, tokens) of live residents, in admission order.
            let mut live: Vec<(u64, usize)> = Vec::new();
            let mut now = 0.0f64;

            for op in ops {
                now += 1.0;
                match *op {
                    Op::Admit { prompt, gen } => {
                        let id = next_id;
                        match pool.admission_plan(prompt, gen) {
                            AdmissionDecision::Defer => {
                                if pool.resident_count() == 0 {
                                    return Err("Defer on an empty pool".into());
                                }
                            }
                            plan => {
                                let cap = match &plan {
                                    AdmissionDecision::Fits { token_capacity, .. }
                                    | AdmissionDecision::Capped { token_capacity, .. }
                                    | AdmissionDecision::EvictThenFit {
                                        token_capacity, ..
                                    } => *token_capacity,
                                    AdmissionDecision::Defer => unreachable!(),
                                };
                                if let AdmissionDecision::EvictThenFit { victims, .. } = &plan {
                                    for v in victims {
                                        live.retain(|(lid, _)| lid != v);
                                    }
                                }
                                let t0 = prompt
                                    .min(cap)
                                    .min(plan.reserved_pages() * pool.config().page_tokens);
                                let admitted = pool
                                    .execute_admission(id, prompt, plan, now)
                                    .map_err(|e| format!("execute_admission: {e}"))?;
                                if !admitted {
                                    return Err("non-Defer plan did not admit".into());
                                }
                                live.push((id, t0));
                                next_id += 1;
                            }
                        }
                    }
                    Op::Grow { victim_slot, tokens } => {
                        if live.is_empty() {
                            continue;
                        }
                        let slot = victim_slot % live.len();
                        let (id, cur) = live[slot];
                        let target = cur + tokens;
                        if pool.ensure_tokens(id, target, now).is_ok() {
                            live[slot].1 = target;
                        }
                        // Denied growth must leave state untouched; the
                        // invariant check below verifies either way.
                    }
                    Op::Complete { victim_slot } => {
                        if live.is_empty() {
                            continue;
                        }
                        let slot = victim_slot % live.len();
                        let (id, _) = live.remove(slot);
                        pool.complete(id).map_err(|e| format!("complete: {e}"))?;
                    }
                    Op::Evict { victim_slot } => {
                        if live.is_empty() {
                            continue;
                        }
                        let slot = victim_slot % live.len();
                        let (id, _) = live.remove(slot);
                        pool.evict(id).map_err(|e| format!("evict: {e}"))?;
                    }
                    Op::Touch { victim_slot } => {
                        if live.is_empty() {
                            continue;
                        }
                        let slot = victim_slot % live.len();
                        pool.touch(live[slot].0, now);
                    }
                }
                pool.check_invariants()?;
                if pool.resident_count() != live.len() {
                    return Err(format!(
                        "model mismatch: pool {} residents vs model {}",
                        pool.resident_count(),
                        live.len()
                    ));
                }
            }
            // Drain and confirm the pool returns to empty.
            for (id, _) in live.drain(..) {
                pool.complete(id).map_err(|e| format!("drain: {e}"))?;
            }
            pool.check_invariants()?;
            if pool.free_pages() != pool.total_pages() {
                return Err("pages leaked after drain".into());
            }
            Ok(())
        },
    );
}

/// Scheduler conservation under admission rejection + retry + preemptive
/// requeue: every request is eventually dispatched, nothing is lost or
/// duplicated beyond its requeues, and `dispatched == admitted + requeued`
/// at drain.
#[test]
fn prop_scheduler_conservation_under_rejection() {
    check(
        cfg(192),
        |rng, size| {
            let n = rng.range(1, size.max(2));
            let policy = if rng.chance(0.5) {
                Policy::SwapPerRequest
            } else {
                Policy::BatchedPhases { max_batch: rng.range(1, 8) }
            };
            // Per-extraction rejection dice + one-shot requeue dice.
            let reject_p = rng.f64() * 0.8;
            let requeue_p = rng.f64() * 0.5;
            let dice_seed = rng.next_u64();
            let mut t = 0.0;
            let reqs: Vec<Request> = (0..n)
                .map(|i| {
                    t += rng.f64();
                    Request::synthetic(i as u64, rng.range(1, 512), rng.range(1, 64), t)
                })
                .collect();
            (policy, reject_p, requeue_p, dice_seed, reqs)
        },
        |(policy, reject_p, requeue_p, dice_seed, reqs)| {
            let mut dice = Rng::new(*dice_seed);
            let mut s = Scheduler::new(*policy);
            for r in reqs.clone() {
                s.admit(r);
            }
            let mut served: Vec<u64> = Vec::new();
            let mut requeued_once = std::collections::HashSet::new();
            let mut guard = 0;
            while !s.is_empty() {
                guard += 1;
                if guard > 100_000 {
                    return Err("scheduler livelock".into());
                }
                let now = s.next_arrival().unwrap_or(f64::MAX);
                // Reject the whole head with probability reject_p, but
                // never forever: alternate attempts always admit.
                let reject_this_round = dice.chance(*reject_p) && guard % 2 == 0;
                let batch = s.next_batch_filtered(now, |_| !reject_this_round);
                for r in batch {
                    // Preempt some requests once, back to the queue front.
                    if dice.chance(*requeue_p) && requeued_once.insert(r.id) {
                        s.requeue_front(r);
                    } else {
                        served.push(r.id);
                    }
                }
            }
            if served.len() != reqs.len() {
                return Err(format!("served {} of {}", served.len(), reqs.len()));
            }
            let mut ids = served.clone();
            ids.sort_unstable();
            ids.dedup();
            if ids.len() != reqs.len() {
                return Err("a request was served twice or lost".into());
            }
            if s.dispatched != s.admitted + s.requeued {
                return Err(format!(
                    "counter conservation broken: dispatched {} != admitted {} + requeued {}",
                    s.dispatched, s.admitted, s.requeued
                ));
            }
            Ok(())
        },
    );
}

/// Regression (issue: `requeue_front` starvation): a long-context
/// request that keeps losing its KV reservation must not park at the
/// queue head forever — the age-based fairness tiebreak lets waiters
/// through as its preemption count grows, and nothing is lost or served
/// twice in the process.
#[test]
fn prop_requeue_fairness_prevents_starvation() {
    check(
        cfg(256),
        |rng, size| {
            let n_waiters = rng.range(1, size.max(2).min(24));
            let preempt_rounds = rng.range(1, 12) as u32;
            (n_waiters, preempt_rounds)
        },
        |&(n_waiters, preempt_rounds)| {
            let mut s = Scheduler::new(Policy::SwapPerRequest);
            // The thrashing long-context request arrives first...
            s.admit(Request::synthetic(0, 2048, 64, 0.0));
            // ...then the waiters it would starve under blind push_front.
            for i in 0..n_waiters {
                s.admit(Request::synthetic(1 + i as u64, 64, 8, 0.1 + i as f64 * 0.1));
            }
            let mut preempts = 0u32;
            let mut served = Vec::new();
            let mut guard = 0;
            while !s.is_empty() {
                guard += 1;
                if guard > 10_000 {
                    return Err("scheduler livelock".into());
                }
                for r in s.next_batch(f64::MAX) {
                    if r.id == 0 && preempts < preempt_rounds {
                        preempts += 1;
                        s.requeue_front(r);
                    } else {
                        served.push(r.id);
                    }
                }
            }
            if served.len() != n_waiters + 1 {
                return Err(format!("served {} of {}", served.len(), n_waiters + 1));
            }
            let mut ids = served.clone();
            ids.sort_unstable();
            ids.dedup();
            if ids.len() != served.len() {
                return Err("a request was served twice".into());
            }
            // Fairness bound: by the time the thrasher finally runs, at
            // least min(preempts − 1, n_waiters) waiters got through.
            // (Under the old blind push_front this count was always 0.)
            let pos = served.iter().position(|&id| id == 0).unwrap();
            let floor = ((preempts as usize).saturating_sub(1)).min(n_waiters);
            if pos < floor {
                return Err(format!(
                    "starvation: only {pos} waiters served before the \
                     {preempts}-times-preempted request (need >= {floor})"
                ));
            }
            if s.dispatched != s.admitted + s.requeued {
                return Err("counter conservation broken".into());
            }
            Ok(())
        },
    );
}

/// Event-driven serving sanity under random traffic, pool pressure, and
/// all three swap policies: every request completes exactly once, the
/// pool drains with balanced accounting, latency accounting stays
/// ordered, and swap-direction counters sum to the reconfiguration
/// total.
#[test]
fn prop_event_server_serves_all() {
    check(
        cfg(32),
        |rng, size| {
            let n = rng.range(1, (size / 6).max(2));
            let policy = match rng.below(3) {
                0 => SwapPolicy::Eager,
                1 => SwapPolicy::hysteresis_default(),
                _ => SwapPolicy::lookahead_default(),
            };
            let total_pages = rng.range(16, 512);
            let admission = if rng.chance(0.5) {
                AdmissionControl::WorstCase
            } else {
                AdmissionControl::Optimistic
            };
            let eviction = if rng.chance(0.5) {
                EvictionPolicy::EvictAndRecompute
            } else {
                EvictionPolicy::KeepResident
            };
            let max_residents = rng.range(1, 8);
            let mut t = 0.0;
            let reqs: Vec<Request> = (0..n)
                .map(|i| {
                    t += rng.f64() * 3.0;
                    // gen 0 included: zero-token decode must complete.
                    Request::synthetic(i as u64, rng.range(1, 1024), rng.below(64), t)
                })
                .collect();
            (policy, total_pages, admission, eviction, max_residents, reqs)
        },
        |(policy, total_pages, admission, eviction, max_residents, reqs)| {
            let mut cfg = EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), *policy);
            cfg.max_residents = *max_residents;
            cfg.pool = cfg
                .pool
                .clone()
                .with_total_pages(*total_pages)
                .with_policies(*admission, *eviction);
            let mut srv = EventServer::new(cfg).map_err(|e| e.to_string())?;
            srv.run(reqs.clone()).map_err(|e| e.to_string())?;
            if srv.metrics.requests_completed.get() != reqs.len() as u64 {
                return Err(format!(
                    "completed {} of {}",
                    srv.metrics.requests_completed.get(),
                    reqs.len()
                ));
            }
            let mut seen: Vec<u64> = srv.outcomes.iter().map(|o| o.id).collect();
            seen.sort_unstable();
            seen.dedup();
            if seen.len() != reqs.len() {
                return Err("an outcome is missing or duplicated".into());
            }
            let max_tokens: usize = reqs.iter().map(|r| r.max_new_tokens).sum();
            if srv.metrics.tokens_generated.get() > max_tokens as u64 {
                return Err("generated more tokens than requested".into());
            }
            if srv.metrics.reconfigurations.get()
                != srv.metrics.swaps_to_prefill.get() + srv.metrics.swaps_to_decode.get()
            {
                return Err("swap-direction counters do not sum to the total".into());
            }
            let pool = srv.pool();
            pool.check_invariants()?;
            if pool.resident_count() != 0 || pool.used_pages() != 0 {
                return Err("pool not drained".into());
            }
            if srv.metrics.kv_evictions.get() != pool.stats.evicted {
                return Err("eviction counters disagree".into());
            }
            for o in &srv.outcomes {
                if o.ttft < 0.0 || o.e2e < o.ttft - 1e-9 || o.mean_tpot < 0.0 {
                    return Err(format!("latency accounting broken: {o:?}"));
                }
            }
            // The timeline is ordered.
            for w in srv.event_log().windows(2) {
                if w[1].at + 1e-9 < w[0].at {
                    return Err("event log out of order".into());
                }
            }
            Ok(())
        },
    );
}

/// Pool-aware serving under random oversubscription: any mix of pool
/// size, policy, and workload completes every request with balanced page
/// accounting and a drained pool.
#[test]
fn prop_sim_server_pool_conservation() {
    check(
        cfg(32),
        |rng, size| {
            let n = rng.range(1, (size / 8).max(2));
            let total_pages = rng.range(4, 256);
            let admission = if rng.chance(0.5) {
                AdmissionControl::WorstCase
            } else {
                AdmissionControl::Optimistic
            };
            let eviction = if rng.chance(0.5) {
                EvictionPolicy::EvictAndRecompute
            } else {
                EvictionPolicy::KeepResident
            };
            let max_batch = rng.range(1, 8);
            let mut t = 0.0;
            let reqs: Vec<Request> = (0..n)
                .map(|i| {
                    t += rng.f64();
                    Request::synthetic(i as u64, rng.range(1, 1024), rng.range(1, 96), t)
                })
                .collect();
            (total_pages, admission, eviction, max_batch, reqs)
        },
        |(total_pages, admission, eviction, max_batch, reqs)| {
            let mut cfg = SimServerConfig::pd_swap(BITNET_0_73B, KV260.clone());
            cfg.policy = Policy::BatchedPhases { max_batch: *max_batch };
            cfg.pool = cfg
                .pool
                .clone()
                .with_total_pages(*total_pages)
                .with_policies(*admission, *eviction);
            let mut srv = SimServer::new(cfg).map_err(|e| e.to_string())?;
            srv.run(reqs.clone()).map_err(|e| e.to_string())?;
            if srv.metrics.requests_completed.get() != reqs.len() as u64 {
                return Err(format!(
                    "completed {} of {}",
                    srv.metrics.requests_completed.get(),
                    reqs.len()
                ));
            }
            let pool = srv.pool();
            pool.check_invariants()?;
            if pool.resident_count() != 0 || pool.used_pages() != 0 {
                return Err("pool not drained".into());
            }
            if pool.stats.high_water_pages > pool.total_pages() {
                return Err("high-water exceeds pool".into());
            }
            if srv.metrics.kv_evictions.get() != pool.stats.evicted {
                return Err("eviction counters disagree".into());
            }
            for o in &srv.outcomes {
                if o.ttft < 0.0 || o.e2e < o.ttft - 1e-9 {
                    return Err(format!("latency accounting broken: {o:?}"));
                }
            }
            Ok(())
        },
    );
}

/// The analytic decode fast-forward is unobservable from the semantic
/// surface: across random traces (Poisson and bursty presets), all
/// three swap policies, decode batches 1 and 4, both arithmetic
/// backends (cached surface vs direct phase model), and both admission
/// regimes under random pool sizes, a run with `fast_forward: true` is
/// bit-identical — clocks, TPOT/TTFT/e2e, outcome order, eviction log —
/// to the same run stepped event by event, and every skipped token-step
/// accounts for exactly one stepped queue event.
#[test]
fn prop_fast_forward_matches_stepped() {
    check(
        cfg(24),
        |rng, _| {
            // Trace family: 0/1 = the historical interactive/bursty
            // shapes, 2 = sparse long-generation, 3 = the decode-heavy
            // `million` preset (scaled down) — the last two are where the
            // interference-aware fold absorbs dormant arrivals.
            let kind = rng.below(4) as usize;
            let n = if kind >= 2 { rng.range(2, 5) } else { rng.range(2, 10) };
            let seed = rng.next_u64();
            let policy = match rng.below(3) {
                0 => SwapPolicy::Eager,
                1 => SwapPolicy::hysteresis_default(),
                _ => SwapPolicy::lookahead_default(),
            };
            let batch = if rng.chance(0.5) { 1usize } else { 4 };
            let use_surface = rng.chance(0.5);
            let optimistic = rng.chance(0.5);
            let total_pages = rng.range(16, 512);
            // Residency axis, including full saturation (max_residents=1
            // makes every mid-decode arrival dormant).
            let residents = *rng.choose(&[1usize, 2, 8]);
            (kind, n, seed, policy, batch, use_surface, optimistic, total_pages, residents)
        },
        |&(kind, n, seed, policy, batch, use_surface, optimistic, total_pages, residents)| {
            let spec = match kind {
                0 => TraceSpec::interactive(n, 0.4, seed),
                1 => TraceSpec::bursty(n, seed),
                2 => TraceSpec::long_decode(n, seed),
                _ => TraceSpec::million(n, seed),
            };
            let reqs = requests_from_trace(&spec.generate());
            let run = |fast_forward: bool| -> Result<EventServer, String> {
                let mut cfg =
                    EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), policy);
                cfg.decode_batch = batch;
                cfg.use_surface = use_surface;
                cfg.fast_forward = fast_forward;
                cfg.max_residents = residents;
                cfg.pool = cfg.pool.clone().with_total_pages(total_pages).with_policies(
                    if optimistic {
                        AdmissionControl::Optimistic
                    } else {
                        AdmissionControl::WorstCase
                    },
                    EvictionPolicy::EvictAndRecompute,
                );
                let mut srv = EventServer::new(cfg).map_err(|e| e.to_string())?;
                srv.run(reqs.clone()).map_err(|e| e.to_string())?;
                Ok(srv)
            };
            let on = run(true)?;
            let off = run(false)?;
            let (a, b) = (semantic_fingerprint(&on), semantic_fingerprint(&off));
            if a != b {
                return Err(format!(
                    "fast-forward changed the timeline\n--- fast-forward\n{a}\n--- stepped\n{b}"
                ));
            }
            let equiv = on
                .fast_forward_stats()
                .stepped_equivalent(on.events_processed());
            if equiv != off.events_processed() {
                return Err(format!(
                    "skipped-step accounting drifted: {} folded-equivalent vs {} stepped",
                    equiv,
                    off.events_processed()
                ));
            }
            if off.fast_forward_stats().steps != 0 {
                return Err("the stepped run must never fold".into());
            }
            Ok(())
        },
    );
}

/// Shrunk regression fixture for the fast-forward equivalence (the
/// smallest hand-reduced shape that exercises every fold stop
/// condition): one long decode that folds freely, an arrival landing
/// mid-generation (horizon stop + mid-decode policy decision), and a
/// pool small enough that decode growth evicts (dry-run stop). Pinned
/// here so a future divergence shrinks to a named, deterministic case.
#[test]
fn prop_fast_forward_regression_fixture() {
    let reqs = vec![
        Request::synthetic(0, 256, 192, 0.0),
        Request::synthetic(1, 96, 24, 5.0),
        Request::synthetic(2, 96, 24, 5.5),
    ];
    let run = |fast_forward: bool| {
        let mut cfg = EventServerConfig::pd_swap(
            BITNET_0_73B,
            KV260.clone(),
            SwapPolicy::lookahead_default(),
        );
        cfg.decode_batch = 4;
        cfg.fast_forward = fast_forward;
        cfg.pool = cfg
            .pool
            .clone()
            .with_total_pages(48)
            .with_policies(AdmissionControl::Optimistic, EvictionPolicy::EvictAndRecompute);
        let mut srv = EventServer::new(cfg).unwrap();
        srv.run(reqs.clone()).unwrap();
        srv
    };
    let on = run(true);
    let off = run(false);
    assert_eq!(semantic_fingerprint(&on), semantic_fingerprint(&off));
    assert!(on.fast_forward_stats().steps > 0, "the fixture must actually fold");
    assert_eq!(
        on.fast_forward_stats().stepped_equivalent(on.events_processed()),
        off.events_processed()
    );
}

/// Streaming is unobservable from the semantic surface: for every trace
/// preset (including the decode-heavy `million` shape), every swap
/// policy, decode batches 1 and 4, both arithmetic backends, and arrival
/// windows down to a single request, `run_streamed` over the lazy
/// arrival stream is bit-identical — clocks, counters, histograms,
/// outcome order and values — to `run` over the materialized workload,
/// and the lazy stream itself replays the materialized generator's RNG
/// draws exactly (`requests_from_stream(spec.stream())` ≡
/// `requests_from_trace(&spec.generate())`).
#[test]
fn prop_streamed_matches_materialized() {
    let presets: [(&str, fn(usize, u64) -> TraceSpec); 4] = [
        ("interactive", |n, s| TraceSpec::interactive(n, 0.4, s)),
        ("bursty", TraceSpec::bursty),
        ("long", TraceSpec::long_decode),
        ("million", TraceSpec::million),
    ];
    for (name, mk) in presets {
        let spec = mk(8, 0xC0FFEE);
        // The stream IS the generator, request for request.
        let eager: Vec<Request> = requests_from_trace(&spec.generate());
        let lazy: Vec<Request> = requests_from_stream(spec.stream()).collect();
        assert_eq!(eager.len(), lazy.len(), "{name}");
        for (a, b) in eager.iter().zip(&lazy) {
            assert_eq!(a.id, b.id, "{name}");
            assert_eq!(a.prompt_len, b.prompt_len, "{name}");
            assert_eq!(a.max_new_tokens, b.max_new_tokens, "{name}");
            assert_eq!(a.arrival.to_bits(), b.arrival.to_bits(), "{name}");
        }
        for policy in [
            SwapPolicy::Eager,
            SwapPolicy::hysteresis_default(),
            SwapPolicy::lookahead_default(),
        ] {
            for batch in [1usize, 4] {
                for use_surface in [true, false] {
                    let mk_srv = || {
                        let mut cfg = EventServerConfig::pd_swap(
                            BITNET_0_73B,
                            KV260.clone(),
                            policy,
                        );
                        cfg.decode_batch = batch;
                        cfg.use_surface = use_surface;
                        EventServer::new(cfg).unwrap()
                    };
                    let mut mat = mk_srv();
                    mat.run(eager.clone()).unwrap();
                    let mat_fp = semantic_fingerprint(&mat);
                    for window in [1usize, 3, 1024] {
                        let mut st = mk_srv();
                        st.run_streamed(requests_from_stream(spec.stream()), window)
                            .unwrap();
                        assert_eq!(
                            mat_fp,
                            semantic_fingerprint(&st),
                            "{name}/{policy:?}/B={batch}/surface={use_surface}/window={window}: \
                             streamed run diverged from materialized"
                        );
                        assert_eq!(st.events_processed(), mat.events_processed());
                        assert_eq!(st.arrivals_total(), mat.arrivals_total());
                    }
                }
            }
        }
    }
}

/// The 5th semantics contract (`docs/ARCHITECTURE.md` extension #10):
/// an explicitly-installed zero-fault plan is *bitwise inert*. Across
/// random traces, all three swap policies, decode batches 1 and 4, and
/// all three execution modes (fast-forward, stepped, streamed), a run
/// with `FaultPlan::none()` — even with a non-default retry policy,
/// whose code paths must never execute without faults — produces the
/// identical [`semantic_fingerprint`] as a config that never mentions
/// the fault layer at all, and no fault metric moves off zero.
#[test]
fn prop_zero_fault_plan_is_bitwise_inert() {
    check(
        cfg(16),
        |rng, _| {
            let kind = rng.below(4) as usize;
            let n = if kind >= 2 { rng.range(2, 5) } else { rng.range(2, 8) };
            let seed = rng.next_u64();
            let policy = match rng.below(3) {
                0 => SwapPolicy::Eager,
                1 => SwapPolicy::hysteresis_default(),
                _ => SwapPolicy::lookahead_default(),
            };
            let batch = if rng.chance(0.5) { 1usize } else { 4 };
            (kind, n, seed, policy, batch)
        },
        |&(kind, n, seed, policy, batch)| {
            let spec = || match kind {
                0 => TraceSpec::interactive(n, 0.4, seed),
                1 => TraceSpec::bursty(n, seed),
                2 => TraceSpec::long_decode(n, seed),
                _ => TraceSpec::million(n, seed),
            };
            let reqs = requests_from_trace(&spec().generate());
            // zero_fault: install the fault layer explicitly (inert plan
            // + a deliberately non-default retry policy). baseline: never
            // touch either field.
            let run = |fast_forward: bool,
                       streamed: bool,
                       zero_fault: bool|
             -> Result<EventServer, String> {
                let mut cfg =
                    EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), policy);
                cfg.decode_batch = batch;
                cfg.fast_forward = fast_forward;
                if zero_fault {
                    cfg.faults = FaultPlan::none();
                    cfg.retry = SwapRetryPolicy::fail_stop();
                }
                let mut srv = EventServer::new(cfg).map_err(|e| e.to_string())?;
                if streamed {
                    srv.run_streamed(requests_from_stream(spec().stream()), 3)
                        .map_err(|e| e.to_string())?;
                } else {
                    srv.run(reqs.clone()).map_err(|e| e.to_string())?;
                }
                Ok(srv)
            };
            let baseline = run(true, false, false)?;
            let fp = semantic_fingerprint(&baseline);
            for (ff, streamed) in [(true, false), (false, false), (true, true)] {
                let srv = run(ff, streamed, true)?;
                let got = semantic_fingerprint(&srv);
                if got != fp {
                    return Err(format!(
                        "zero-fault plan moved a bit (ff={ff} streamed={streamed})\
                         \n--- baseline\n{fp}\n--- zero-fault\n{got}"
                    ));
                }
                if srv.metrics.requests_shed.get() != 0
                    || srv.metrics.swap_failures.get() != 0
                    || srv.metrics.swap_retries.get() != 0
                    || srv.metrics.degraded_seconds != 0.0
                {
                    return Err("zero-fault run moved a fault metric".into());
                }
            }
            if fp.contains("shed ") || fp.contains("faults ") {
                return Err("zero-fault fingerprint leaked fault lines".into());
            }
            Ok(())
        },
    );
}

/// Faulted runs are deterministic per mode: the same `--fault-seed`
/// yields byte-identical metrics summaries, semantic fingerprints, and
/// Chrome traces across repeated runs — including runs executed on
/// `util::par` worker threads at several thread counts (the fault layer
/// keeps no global or thread-local state).
#[test]
fn prop_fault_seed_runs_are_byte_identical() {
    for (spec, family) in [
        (FaultSpec::SwapStorm, "bursty"),
        (FaultSpec::DdrBrownout, "bursty"),
        (FaultSpec::Deadlines, "interactive"),
        (FaultSpec::Chaos, "interactive"),
    ] {
        let trace = match family {
            "interactive" => TraceSpec::interactive(8, 0.4, 0xFA17),
            _ => TraceSpec::bursty(8, 0xFA17),
        };
        let reqs = requests_from_trace(&trace.generate());
        let run = || {
            let mut cfg = EventServerConfig::pd_swap(
                BITNET_0_73B,
                KV260.clone(),
                SwapPolicy::Eager,
            );
            cfg.trace = true;
            cfg.faults = FaultPlan::from_spec(spec, 0xDEC0DE, family);
            let mut srv = EventServer::new(cfg).unwrap();
            srv.run(reqs.clone()).unwrap();
            (
                semantic_fingerprint(&srv),
                srv.metrics.summary_json().to_pretty(),
                srv.recorder.to_chrome_json().to_pretty(),
            )
        };
        let reference = run();
        let rerun = run();
        assert_eq!(reference, rerun, "{spec:?}: rerun diverged");
        for threads in [1usize, 2, 4] {
            let results = par_map(&[(); 4], threads, |_| run());
            for (i, r) in results.iter().enumerate() {
                assert_eq!(
                    reference, *r,
                    "{spec:?}: run {i} at {threads} threads diverged"
                );
            }
        }
    }
}

/// Resource vector algebra: fits_within is monotone under addition of
/// non-negative vectors; max is an upper bound of both arguments.
#[test]
fn prop_resource_algebra() {
    check(
        cfg(512),
        |rng, _| {
            let r = |rng: &mut Rng| ResourceVec {
                lut: rng.f64() * 1e5,
                ff: rng.f64() * 2e5,
                bram36: rng.f64() * 150.0,
                uram: rng.f64() * 64.0,
                dsp: rng.f64() * 1250.0,
            };
            (r(rng), r(rng))
        },
        |(a, b)| {
            let m = a.max(b);
            if !a.fits_within(&m) || !b.fits_within(&m) {
                return Err("max is not an upper bound".into());
            }
            let sum = *a + *b;
            if !a.fits_within(&sum) {
                return Err("addition broke monotonicity".into());
            }
            if !(sum - *a).is_nonnegative() {
                return Err("subtraction broke non-negativity".into());
            }
            Ok(())
        },
    );
}
