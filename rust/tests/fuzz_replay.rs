//! Replays the committed fuzz corpus and pins the fuzzer's determinism.
//!
//! `rust/tests/fuzz_corpus/` holds shrunk [`pd_swap::fuzz::Fixture`]
//! files — tricky corners of the configuration cross-product pinned so
//! they run on every `cargo test` forever. Each must replay *clean*:
//! a corpus fixture diverging again means a semantics contract broke.
//!
//! To add a fixture: take the JSON that `pd-swap fuzz` writes under
//! `--out` on a divergence, fix the bug, confirm
//! `pd-swap fuzz --replay <file>` reports clean, then commit the file
//! here (see README §"Fuzzing quickstart").

use pd_swap::fuzz::{replay_file, run_fuzz, FuzzConfig, OracleOptions};

fn corpus_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fuzz_corpus")
}

#[test]
fn corpus_fixtures_replay_clean() {
    let mut paths: Vec<_> = std::fs::read_dir(corpus_dir())
        .expect("rust/tests/fuzz_corpus must exist")
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
    paths.sort();
    assert!(!paths.is_empty(), "the corpus must contain at least one fixture");
    for p in &paths {
        let (fx, diverged) = replay_file(p, OracleOptions::default())
            .unwrap_or_else(|e| panic!("{}: {e}", p.display()));
        assert!(
            diverged.is_none(),
            "{}: corpus fixture diverges again: {:?}\n  case: {:?}",
            p.display(),
            diverged,
            fx.case
        );
    }
}

#[test]
fn fuzz_smoke_seed_is_clean_and_deterministic() {
    // The CI invocation in miniature: the committed smoke seed over a
    // reduced case count must find nothing, and re-running it must
    // reproduce the summary byte for byte (the acceptance pin for
    // `pd-swap fuzz --cases 64 --seed 0x5EED`).
    let cfg = FuzzConfig { cases: 8, seed: 0x5EED, max_requests: 6, out_dir: None };
    let a = run_fuzz(&cfg, OracleOptions::default()).unwrap();
    assert_eq!(a.divergences, 0, "{}", a.report);
    assert_eq!(a.cases_run, 8);
    let b = run_fuzz(&cfg, OracleOptions::default()).unwrap();
    assert_eq!(a.report, b.report, "summary must be byte-identical across reruns");
}
