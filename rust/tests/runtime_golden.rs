//! Cross-layer integration test: the Rust PJRT execution of the AOT
//! artifacts must reproduce the golden greedy-generation trace that
//! `aot.py` computed with the same jitted JAX functions.
//!
//! Requires `make artifacts` to have run (skips with a note otherwise, so
//! `cargo test` stays green on a fresh checkout) and the `pjrt` cargo
//! feature (the whole file is a no-op without it).

#![cfg(feature = "pjrt")]

use pd_swap::runtime::{argmax, InferenceEngine};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/test");
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn golden_greedy_trace_matches() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let engine = InferenceEngine::load(&dir).expect("engine load");
    let golden = engine
        .artifacts
        .load_golden()
        .expect("golden load")
        .expect("test config must ship golden.json");

    // 1. Prefill logits prefix must match to float tolerance.
    let pre = engine.prefill(&golden.prompt).expect("prefill");
    assert_eq!(pre.bucket, golden.bucket, "bucket selection diverged");
    for (i, (&got, &want)) in pre
        .logits
        .iter()
        .zip(&golden.first_logits_prefix)
        .enumerate()
    {
        assert!(
            (got - want).abs() <= 1e-3 + 1e-3 * want.abs(),
            "logit[{i}]: rust={got} python={want}"
        );
    }

    // 2. Greedy generation must match token-for-token.
    let generated = engine
        .generate_greedy(&golden.prompt, golden.n_gen)
        .expect("generate");
    assert_eq!(generated, golden.generated, "greedy trace diverged");
}

#[test]
fn decode_respects_cache_capacity() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let engine = InferenceEngine::load(&dir).expect("engine load");
    let max_seq = engine.max_seq();

    let pre = engine.prefill(&[1, 2, 3]).expect("prefill");
    let mut cache = pre.cache;
    let mut tok = argmax(&pre.logits);
    // Fill the cache to the brim ...
    while cache.has_room() {
        let (logits, c) = engine.decode(tok, cache).expect("decode");
        cache = c;
        tok = argmax(&logits);
    }
    assert_eq!(cache.len, max_seq);
    // ... and the next decode must fail loudly, not corrupt state.
    let err = engine.decode(tok, cache).unwrap_err();
    assert!(err.to_string().contains("full"), "unexpected error: {err}");
}

#[test]
fn prefill_bucket_selection_and_overflow() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let engine = InferenceEngine::load(&dir).expect("engine load");
    let buckets = engine.buckets();

    // A prompt exactly at each bucket boundary compiles to that bucket.
    for &b in &buckets {
        let prompt: Vec<i32> = (0..b as i32).map(|i| i % 7 + 1).collect();
        let pre = engine.prefill(&prompt).expect("prefill");
        assert_eq!(pre.bucket, b);
        assert_eq!(pre.cache.len, b);
    }

    // A prompt longer than the largest bucket is rejected.
    let too_long = vec![1i32; buckets.last().unwrap() + 1];
    assert!(engine.prefill(&too_long).is_err());

    // Empty prompts are rejected.
    assert!(engine.prefill(&[]).is_err());
}

#[test]
fn prefill_padding_is_invisible() {
    // The same prompt must produce the same logits whether it lands in the
    // small or the large bucket — right-padding + causal masking must not
    // leak into the valid positions. We force the big bucket by lengthening
    // the prompt with a common prefix... actually by comparing the common
    // prefix computation: prompt P in bucket b1, and P' = P padded into a
    // longer *prompt* is a different computation, so instead compare
    // prefill(P) against decode-reconstruction: prefill(P[..n-1]) + decode.
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let engine = InferenceEngine::load(&dir).expect("engine load");

    let prompt = [1i32, 2, 3, 4, 5, 6];
    let full = engine.prefill(&prompt).expect("prefill full");

    // Reconstruct: prefill all but the last token, then decode it.
    let pre = engine.prefill(&prompt[..5]).expect("prefill prefix");
    let (logits, _cache) = engine.decode(prompt[5], pre.cache).expect("decode");

    for (i, (a, b)) in full.logits.iter().zip(&logits).enumerate() {
        assert!(
            (a - b).abs() <= 2e-3 + 2e-3 * b.abs(),
            "prefill-vs-decode logits diverge at {i}: {a} vs {b}"
        );
    }
}
