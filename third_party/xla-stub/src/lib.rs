//! Compile-only stub of the `xla` PJRT bindings (see README.md).
//!
//! Mirrors the slice of the xla_extension 0.5.1-era API that
//! `pd_swap::runtime` uses, with every runtime entry point returning
//! [`Error::NotLinked`]. This keeps `--features pjrt` type-checking on
//! machines without an XLA installation; swap in the real bindings via a
//! `[patch]` to actually execute artifacts.

use std::fmt;

/// The stub's only error: PJRT is not linked.
#[derive(Debug, Clone)]
pub enum Error {
    NotLinked(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::NotLinked(what) => {
                write!(f, "xla stub: PJRT not linked (called {what}); build against the real xla bindings")
            }
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn not_linked<T>(what: &'static str) -> Result<T> {
    Err(Error::NotLinked(what))
}

/// Element types the manifest dtypes map onto.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    U8,
    S32,
}

/// Host tensor elements transferable to device buffers.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}
impl NativeType for u32 {}

/// A host literal (stub: carries no data).
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn size_bytes(&self) -> usize {
        0
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        not_linked("Literal::to_vec")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        not_linked("Literal::to_tuple")
    }
}

/// A device buffer handle (stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        not_linked("PjRtBuffer::to_literal_sync")
    }
}

/// A parsed HLO module proto (stub).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        not_linked("HloModuleProto::from_text_file")
    }
}

/// An XLA computation (stub).
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _private: () }
    }
}

/// A compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        not_linked("PjRtLoadedExecutable::execute_b")
    }
}

/// A PJRT client (stub).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        not_linked("PjRtClient::cpu")
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        not_linked("PjRtClient::buffer_from_host_buffer")
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        not_linked("PjRtClient::buffer_from_host_literal")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        not_linked("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_reports_not_linked() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("not linked"));
    }
}
