//! Bench: KV-pool-aware serving — `SwapPerRequest` vs. cache-aware
//! `BatchedPhases` at long contexts (4k / 16k / 32k total tokens).
//!
//! Uses a long-context variant of the e2e-100m shape so that the 32k
//! workload genuinely oversubscribes the KV260's modeled DDR KV budget
//! (~4k pages): worst-case admission splits the queue into pool-bounded
//! phase-batches, and batched mode amortizes one swap pair per batch
//! instead of per request. Reported tokens/s and p95 E2E are *simulated
//! KV260* numbers (the wall-clock cost of the simulation itself is also
//! measured, via `util::bench`).
//!
//! Emits `BENCH_kvpool.json` (override with `-- --out PATH`). All JSON
//! report fields are deterministic virtual-clock values; `-- --smoke`
//! only trims the host wall-clock measurement section (CI's bench-smoke
//! mode), leaving the report byte-identical to a full run.
//!
//! Run: `cargo bench --bench kvpool_serving`

use pd_swap::coordinator::{Policy, Request, SimServer, SimServerConfig};
use pd_swap::fpga::KV260;
use pd_swap::model::{ModelShape, Precision};
use pd_swap::util::bench;
use pd_swap::util::cli::Args;
use pd_swap::util::json::Value;

/// e2e-100m widened to a 32k context window: small enough that long
/// contexts fit DDR, big enough that six of them do not.
const LONG_CTX: ModelShape = ModelShape {
    name: "e2e-100m-32k",
    n_layers: 10,
    d_model: 768,
    n_heads: 12,
    d_ff: 3072,
    vocab: 8192,
    max_seq: 32 * 1024,
    kv_precision: Precision::Fp16,
};

const GEN_TOKENS: usize = 64;
const N_REQUESTS: u64 = 6;

struct PolicyRun {
    tokens_per_sec: f64,
    p95_e2e: f64,
    swaps: u64,
    tokens: u64,
    high_water_pages: u64,
    batches_deferred: bool,
}

fn run_policy(policy: Policy, context: usize) -> PolicyRun {
    let mut cfg = SimServerConfig::pd_swap(LONG_CTX, KV260.clone());
    cfg.policy = policy;
    let prompt = context.saturating_sub(GEN_TOKENS).max(1);
    let aggregate_worst =
        cfg.pool.worst_case_pages(prompt, GEN_TOKENS) * N_REQUESTS as usize;
    let oversubscribed = aggregate_worst > cfg.pool.total_pages;
    let wl: Vec<Request> = (0..N_REQUESTS)
        .map(|i| Request::synthetic(i, prompt, GEN_TOKENS, 0.0))
        .collect();
    let mut srv = SimServer::new(cfg).expect("config must program");
    srv.run(wl).expect("serving must not fail under oversubscription");
    assert_eq!(srv.metrics.requests_completed.get(), N_REQUESTS);
    srv.pool().check_invariants().expect("pool accounting balances at drain");

    let tokens = srv.metrics.tokens_generated.get();
    PolicyRun {
        tokens_per_sec: tokens as f64 / srv.clock().max(1e-12),
        p95_e2e: srv.metrics.e2e.quantile(0.95),
        swaps: srv.metrics.reconfigurations.get(),
        tokens,
        high_water_pages: srv.metrics.kv_pool_high_water.get(),
        batches_deferred: oversubscribed,
    }
}

fn run_json(r: &PolicyRun) -> Value {
    Value::Obj(vec![
        ("tokens_per_sec".into(), Value::Num(r.tokens_per_sec)),
        ("p95_e2e_s".into(), Value::Num(r.p95_e2e)),
        ("swaps".into(), Value::Num(r.swaps as f64)),
        ("tokens".into(), Value::Num(r.tokens as f64)),
        ("pool_high_water_pages".into(), Value::Num(r.high_water_pages as f64)),
    ])
}

fn main() {
    let args = Args::from_env();
    let out = args.get_or("out", "BENCH_kvpool.json");
    let contexts = args.get_usize_list("contexts", &[4 * 1024, 16 * 1024, 32 * 1024]);

    let pool_cfg = SimServerConfig::pd_swap(LONG_CTX, KV260.clone()).pool;
    bench::section("KV pool");
    println!(
        "model {}: {:.1} KB KV/token; pool {} pages x {} tokens = {:.2} GB budget",
        LONG_CTX.name,
        LONG_CTX.kv_bytes_per_token() / 1e3,
        pool_cfg.total_pages,
        pool_cfg.page_tokens,
        pool_cfg.budget_bytes() / 1e9,
    );

    bench::section(&format!(
        "{N_REQUESTS} simultaneous requests, {GEN_TOKENS} new tokens each (simulated KV260)"
    ));
    println!(
        "{:>8}  {:>12} {:>12} {:>7}  | {:>12} {:>12} {:>7}  {:>9}",
        "context", "per-req t/s", "p95 e2e s", "swaps", "batched t/s", "p95 e2e s", "swaps",
        "speedup"
    );

    let mut rows = Vec::new();
    let mut all_hold = true;
    for &ctx in &contexts {
        let per_req = run_policy(Policy::SwapPerRequest, ctx);
        let batched = run_policy(Policy::BatchedPhases { max_batch: 8 }, ctx);
        let speedup = batched.tokens_per_sec / per_req.tokens_per_sec.max(1e-12);
        println!(
            "{:>8}  {:>12.2} {:>12.1} {:>7}  | {:>12.2} {:>12.1} {:>7}  {:>8.2}x",
            ctx,
            per_req.tokens_per_sec,
            per_req.p95_e2e,
            per_req.swaps,
            batched.tokens_per_sec,
            batched.p95_e2e,
            batched.swaps,
            speedup,
        );
        // The acceptance bar: cache-aware batching matches or beats the
        // paper's per-request flow at every context length.
        if batched.tokens_per_sec + 1e-12 < per_req.tokens_per_sec {
            all_hold = false;
        }
        rows.push(Value::Obj(vec![
            ("context".into(), Value::Num(ctx as f64)),
            ("per_request".into(), run_json(&per_req)),
            ("batched".into(), run_json(&batched)),
            ("speedup".into(), Value::Num(speedup)),
            ("oversubscribed".into(), Value::Bool(batched.batches_deferred)),
        ]));
    }
    assert!(
        all_hold,
        "BatchedPhases must match or beat SwapPerRequest tokens/s at every context"
    );

    // Wall-clock cost of the simulation itself (not KV260 time).
    if !args.flag("smoke") {
        bench::section("simulation wall-clock");
        let s = bench::run("32k oversubscribed serve (both policies)", 1, 5, || {
            std::hint::black_box(run_policy(Policy::BatchedPhases { max_batch: 8 }, 32 * 1024));
            std::hint::black_box(run_policy(Policy::SwapPerRequest, 32 * 1024));
        });
        println!("{s}");
    }

    let report = Value::Obj(vec![
        ("bench".into(), Value::Str("kvpool_serving".into())),
        ("model".into(), Value::Str(LONG_CTX.name.into())),
        ("n_requests".into(), Value::Num(N_REQUESTS as f64)),
        ("gen_tokens".into(), Value::Num(GEN_TOKENS as f64)),
        ("pool_total_pages".into(), Value::Num(pool_cfg.total_pages as f64)),
        ("page_tokens".into(), Value::Num(pool_cfg.page_tokens as f64)),
        ("contexts".into(), Value::Arr(rows)),
    ]);
    match bench::write_json_report(out, &report) {
        Ok(p) => println!("\nwrote {p}"),
        Err(e) => eprintln!("\ncould not write {out}: {e}"),
    }
}
