//! Bench: regenerate Table 2 (resource breakdown) and time the floorplan
//! pipeline (engine costing -> RP planning -> validation).
//!
//! Run: `cargo bench --bench table2_resources`

use pd_swap::engines::AcceleratorDesign;
use pd_swap::eval::run_table2;
use pd_swap::fpga::KV260;
use pd_swap::util::bench;

fn main() {
    bench::section("Table 2 — FPGA resource consumption breakdown");
    let (rows, total, equivalent) = run_table2();

    bench::section("paper vs measured (headline numbers)");
    for (name, got, want) in [
        ("Total LUT", total.lut, 102_102.0),
        ("Equivalent LUT", equivalent.lut, 124_780.0),
        ("Total DSP", total.dsp, 750.0),
        ("Total URAM", total.uram, 62.0),
    ] {
        println!(
            "{name:20} measured {got:9.0}  paper {want:9.0}  delta {:+6.1}%",
            (got / want - 1.0) * 100.0
        );
    }
    println!("({} module rows compared above)", rows.len());

    bench::section("timing");
    let s = bench::run("floorplan + validate", 10, 200, || {
        let d = AcceleratorDesign::pd_swap();
        let plan = d.region_plan().unwrap();
        std::hint::black_box(plan.validate(&KV260).unwrap());
    });
    println!("{s}");
}
