//! Bench: regenerate Fig. 5 (latency-overlapped reconfiguration) and run
//! the serving-level A/B (overlap on/off) that the figure motivates.
//!
//! Run: `cargo bench --bench fig5_overlap`

use pd_swap::coordinator::{Request, SimServer, SimServerConfig};
use pd_swap::eval::run_fig5;
use pd_swap::fpga::KV260;
use pd_swap::model::BITNET_0_73B;
use pd_swap::util::bench;

fn main() {
    bench::section("Fig. 5 — latency-overlapped runtime reconfiguration");
    let reports = run_fig5();

    let at128 = reports.iter().find(|r| r.l == 128).unwrap();
    bench::section("paper vs measured @ L=128");
    println!(
        "reconfig    measured {:5.1} ms  paper ~45 ms",
        at128.reconfig_ms
    );
    println!(
        "tail        measured {:5.1} ms  paper ~31 ms",
        at128.tail_ms
    );
    println!(
        "hidden      measured {:5.0}%    paper ~75%",
        at128.hidden_fraction * 100.0
    );

    // Serving-level A/B: 8 short requests, overlap on vs off.
    bench::section("serving A/B (8 short requests, L=128, 16 tokens each)");
    let reqs: Vec<Request> = (0..8)
        .map(|i| Request::synthetic(i, 128, 16, i as f64 * 0.1))
        .collect();
    let mut on = SimServer::new(SimServerConfig::pd_swap(BITNET_0_73B, KV260.clone())).unwrap();
    on.run(reqs.clone()).unwrap();
    let mut cfg_off = SimServerConfig::pd_swap(BITNET_0_73B, KV260.clone());
    cfg_off.overlap = false;
    let mut off = SimServer::new(cfg_off).unwrap();
    off.run(reqs).unwrap();
    println!(
        "overlap ON : mean exposed {:5.1} ms, mean TTFT {:6.1} ms",
        on.metrics.reconfig_exposed.mean() * 1e3,
        on.metrics.ttft.mean() * 1e3
    );
    println!(
        "overlap OFF: mean exposed {:5.1} ms, mean TTFT {:6.1} ms",
        off.metrics.reconfig_exposed.mean() * 1e3,
        off.metrics.ttft.mean() * 1e3
    );

    bench::section("timing");
    let s = bench::run("overlap timeline analysis (5 lengths)", 5, 100, || {
        std::hint::black_box(pd_swap::eval::fig5::analyze(&[64, 128, 256, 512, 1024]));
    });
    println!("{s}");
}
