//! Bench: regenerate Fig. 4a (roofline analysis) and time the analysis.
//!
//! Run: `cargo bench --bench fig4_roofline`

use pd_swap::eval::run_fig4a;
use pd_swap::roofline::Bound;
use pd_swap::util::bench;

fn main() {
    bench::section("Fig. 4a — qualitative roofline, computed");
    let results = run_fig4a();

    bench::section("paper vs measured (regime placement)");
    let (_, pts) = &results[1]; // L = 512
    for p in pts {
        let expected = match p.kernel.as_str() {
            "decode-attention" => Bound::Memory,
            "prefill-attention" => Bound::Compute,
            // Decode/prefill linear: streaming-roof bound in our model
            // (weights cannot reside on-chip at 0.73B).
            _ => p.bound,
        };
        println!(
            "{:20} AI {:8.2} MAC/B  bound {:?}  (paper: {:?})  {}",
            p.kernel,
            p.arithmetic_intensity,
            p.bound,
            expected,
            if p.bound == expected { "match" } else { "MISMATCH" }
        );
    }

    bench::section("timing");
    let s = bench::run("roofline analysis (3 lengths x 4 kernels)", 10, 200, || {
        std::hint::black_box(pd_swap::eval::fig4::analyze(&[128, 512, 2048]));
    });
    println!("{s}");
}
