//! Bench: regenerate Table 1 (cross-platform comparison) and time the
//! simulator queries behind it.
//!
//! Run: `cargo bench --bench table1_cross_platform`

use pd_swap::eval::run_table1;
use pd_swap::util::bench;

fn main() {
    bench::section("Table 1 — unified cross-platform comparison");
    let rows = run_table1();

    // Paper-vs-measured deltas for the computed rows.
    bench::section("paper vs measured");
    let pd = rows.iter().find(|r| r.work.contains("PD-Swap")).unwrap();
    let te = rows.iter().find(|r| r.work.contains("TeLLMe")).unwrap();
    for (name, got, want) in [
        ("PD-Swap decode TK/s", pd.decode_tks, 27.8),
        ("PD-Swap decode TK/J", pd.decode_tkj(), 5.67),
        ("TeLLMe decode TK/s", te.decode_tks, 25.0),
        ("TeLLMe decode TK/J", te.decode_tkj(), 5.2),
    ] {
        println!(
            "{name:24} measured {got:7.2}  paper {want:7.2}  delta {:+6.1}%",
            (got / want - 1.0) * 100.0
        );
    }

    bench::section("timing");
    let s = bench::run("table1 full computation", 3, 50, || {
        std::hint::black_box(pd_swap::eval::table1::rows());
    });
    println!("{s}");
}
