//! Bench: SLO-goodput under a deterministic swap-failure storm —
//! retry + degraded fallback vs naive fail-stop (extension #10).
//!
//! The claim this bench exists to gate: when PCAP reconfigurations
//! start failing, a node that retries with capped exponential backoff
//! and falls back to the static-unified surface keeps serving — its
//! SLO-weighted goodput stays strictly above a fail-stop node that
//! sheds everything outstanding the moment one swap exhausts its
//! retry budget. Gated by `benches/baselines/BENCH_fault.json`:
//!
//! 1. **Goodput ratio** (`storm.goodput_ratio`, hard ≥ 1.2): both
//!    policies serve the same bursty trace under the same seeded
//!    [`FaultPlan::storm`]; goodput is `slo_goodput_tps(makespan) ×
//!    slo_attainment` — tokens that reached *completed* requests per
//!    second, discounted by the completed fraction, the same number
//!    the codesign sweep reports per cell. The fallback policy must
//!    beat fail-stop by ≥ 20%.
//! 2. **Fallback completes** (`storm.fallback_completed_frac`, hard
//!    ≥ 0.9): the storm plan carries no deadlines, so the degraded
//!    path must finish every request — shedding here would mean the
//!    retry/fallback machinery lost work it had no license to drop.
//! 3. **Fail-stop actually trips** (`storm.failstop_sheds`, hard
//!    ≥ 1): the comparison is meaningless if the chosen seed never
//!    exhausts a retry budget, so the bench deterministically scans
//!    seed candidates and records the one it used.
//!
//! Everything runs on the virtual clock — the reported goodput is a
//! deterministic function of (trace seed, fault seed, policy), byte
//! for byte, which the bench asserts by rerunning the fallback leg.
//!
//! Run: `cargo bench --bench fault_tolerance` (CI adds `-- --smoke`)

use pd_swap::coordinator::{
    requests_from_trace, semantic_fingerprint, EventServer, EventServerConfig, Request,
};
use pd_swap::faults::FaultPlan;
use pd_swap::fpga::KV260;
use pd_swap::model::{TraceSpec, BITNET_0_73B};
use pd_swap::reconfig::{SwapPolicy, SwapRetryPolicy};
use pd_swap::util::bench;
use pd_swap::util::cli::Args;
use pd_swap::util::json::Value;

/// Storm intensity: per-attempt PCAP failure probability. At the
/// default 3-attempt retry budget this exhausts ~21.6% of swaps, so a
/// fail-stop node trips early in any multi-swap run while the
/// fallback node spends only short windows degraded.
const STORM_PROB: f64 = 0.6;

/// Requests in the bursty trace. Small enough to stay milliseconds,
/// large enough that an early fail-stop trip strands most of the
/// workload.
const N_REQUESTS: usize = 24;

/// One storm run: the paper design under Eager swapping (maximum swap
/// traffic — the regime fault tolerance is for), bursty arrivals, the
/// given retry policy against `FaultPlan::storm(fault_seed)`.
fn run_storm(reqs: &[Request], fault_seed: u64, retry: SwapRetryPolicy) -> EventServer {
    let mut cfg = EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), SwapPolicy::Eager);
    cfg.faults = FaultPlan::storm(fault_seed, STORM_PROB);
    cfg.retry = retry;
    let mut srv = EventServer::new(cfg).expect("config must program");
    srv.run(reqs.to_vec()).expect("serving must not fail");
    srv
}

/// SLO-weighted goodput: tokens that reached completed requests per
/// second of virtual makespan, discounted by the completed fraction.
/// The attainment factor is what separates the policies — a fail-stop
/// node's clock stops when it trips, so raw tokens/makespan alone
/// would flatter it.
fn slo_goodput(srv: &EventServer) -> f64 {
    srv.metrics.slo_goodput_tps(srv.clock()) * srv.metrics.slo_attainment()
}

fn main() {
    let args = Args::from_env();
    let out = args.get_or("out", "BENCH_fault.json");
    let smoke = args.flag("smoke");

    let spec = TraceSpec::bursty(N_REQUESTS, 0xB0B);
    let reqs = requests_from_trace(&spec.generate());
    let n = reqs.len() as u64;

    // -- pick a storm seed that actually trips fail-stop -------------------
    // A hard-coded seed would gate on luck; instead scan a small
    // deterministic candidate list and use the first seed whose
    // fail-stop run strands at least half the workload. At p = 0.6 the
    // first candidate trips with overwhelming probability — the scan is
    // insurance, and the chosen seed lands in the report either way.
    bench::section("storm seed scan (first seed stranding >= half the workload)");
    let mut chosen = None;
    for seed in 1..=16u64 {
        let failstop = run_storm(&reqs, seed, SwapRetryPolicy::fail_stop());
        let shed = failstop.metrics.requests_shed.get();
        println!("  seed {seed}: fail-stop sheds {shed}/{n}");
        if shed >= n.div_ceil(2) {
            chosen = Some((seed, failstop));
            break;
        }
    }
    let (seed, failstop) = chosen.expect(
        "no storm seed in 1..=16 strands half the workload under fail-stop — \
         the retry/fault wiring has drifted",
    );

    // -- the comparison: retry + degraded fallback vs fail-stop ------------
    bench::section("retry + degraded fallback vs fail-stop (same seed, same trace)");
    let fallback = run_storm(&reqs, seed, SwapRetryPolicy::default());
    let m_fb = &fallback.metrics;
    let m_fs = &failstop.metrics;

    // The storm plan has no deadlines: nothing licenses the fallback
    // node to shed, so it must complete everything.
    assert_eq!(
        m_fb.requests_shed.get(),
        0,
        "fallback shed requests under a deadline-free storm"
    );
    assert_eq!(
        m_fb.requests_completed.get(),
        n,
        "fallback must complete the full workload"
    );
    // Fail-stop tripped (the scan guarantees sheds), so the same draw
    // stream must have exhausted a retry budget on the fallback side
    // too — which is exactly what puts it into degraded mode.
    assert!(
        m_fb.swap_failures.get() >= u64::from(SwapRetryPolicy::default().max_attempts),
        "fallback saw fewer swap failures than one exhausted retry budget"
    );
    assert!(
        m_fb.degraded_seconds > 0.0,
        "retry exhaustion must put the fallback node into degraded mode"
    );

    let goodput_fb = slo_goodput(&fallback);
    let goodput_fs = slo_goodput(&failstop);
    let ratio = goodput_fb / goodput_fs.max(1e-12);
    let completed_frac = m_fb.requests_completed.get() as f64 / n as f64;
    println!(
        "fallback:  {}/{n} completed, {} swap failures / {} retries, {:.3}s degraded, \
         {goodput_fb:.2} tok/s SLO-goodput over {:.2}s",
        m_fb.requests_completed.get(),
        m_fb.swap_failures.get(),
        m_fb.swap_retries.get(),
        m_fb.degraded_seconds,
        fallback.clock(),
    );
    println!(
        "fail-stop: {}/{n} completed ({} shed), {goodput_fs:.2} tok/s SLO-goodput over {:.2}s",
        m_fs.requests_completed.get(),
        m_fs.requests_shed.get(),
        failstop.clock(),
    );
    println!("SLO-goodput ratio (fallback / fail-stop): {ratio:.2}x");
    assert!(
        ratio > 1.0,
        "retry + fallback goodput {goodput_fb:.2} not strictly above fail-stop {goodput_fs:.2}"
    );
    assert!(
        ratio >= 1.2,
        "goodput ratio {ratio:.2}x below the 1.2x bar the baseline gates"
    );

    // -- determinism: the reported number is a pure function of seeds ------
    bench::section("determinism (rerun the fallback leg, compare fingerprints)");
    let rerun = run_storm(&reqs, seed, SwapRetryPolicy::default());
    assert_eq!(
        semantic_fingerprint(&fallback),
        semantic_fingerprint(&rerun),
        "same fault seed must reproduce the fallback run byte for byte"
    );
    println!("rerun fingerprint identical");

    let report = Value::Obj(vec![
        ("bench".into(), Value::Str("fault_tolerance".into())),
        ("smoke".into(), Value::Num(u8::from(smoke) as f64)),
        (
            "storm".into(),
            Value::Obj(vec![
                ("seed".into(), Value::Num(seed as f64)),
                ("swap_fail_prob".into(), Value::Num(STORM_PROB)),
                ("requests".into(), Value::Num(n as f64)),
                (
                    "fallback".into(),
                    Value::Obj(vec![
                        ("completed".into(), Value::Num(m_fb.requests_completed.get() as f64)),
                        ("shed".into(), Value::Num(m_fb.requests_shed.get() as f64)),
                        ("swap_failures".into(), Value::Num(m_fb.swap_failures.get() as f64)),
                        ("swap_retries".into(), Value::Num(m_fb.swap_retries.get() as f64)),
                        ("degraded_seconds".into(), Value::Num(m_fb.degraded_seconds)),
                        ("slo_goodput_tps".into(), Value::Num(goodput_fb)),
                        ("makespan_s".into(), Value::Num(fallback.clock())),
                    ]),
                ),
                (
                    "failstop".into(),
                    Value::Obj(vec![
                        ("completed".into(), Value::Num(m_fs.requests_completed.get() as f64)),
                        ("shed".into(), Value::Num(m_fs.requests_shed.get() as f64)),
                        ("slo_goodput_tps".into(), Value::Num(goodput_fs)),
                        ("makespan_s".into(), Value::Num(failstop.clock())),
                    ]),
                ),
                ("goodput_ratio".into(), Value::Num(ratio)),
                ("fallback_completed_frac".into(), Value::Num(completed_frac)),
                ("failstop_sheds".into(), Value::Num(m_fs.requests_shed.get() as f64)),
            ]),
        ),
    ]);
    match bench::write_json_report(out, &report) {
        Ok(p) => println!("\nwrote {p}"),
        Err(e) => eprintln!("\ncould not write {out}: {e}"),
    }
}
