//! Bench: the analytic hot-path kernel — surface-cached vs uncached
//! latency evaluation, measured where it matters: a single model query,
//! the full §4.3 DSE grid (`dse::explore`), and a mixed-trace
//! `EventServer` run whose per-token-step events hammer the model.
//!
//! Both paths are *bit-identical by construction* (the surface caches the
//! closed-form coefficients, not sampled values), so this bench first
//! proves agreement — max relative error across the paper grid, the
//! context breakpoints, and page sizes must be ≤ 1e-9 (it is exactly 0) —
//! and only then measures the speedup. Hard acceptance asserted here and
//! gated by `benches/baselines/BENCH_hotpath.json`:
//!
//! * cached `explore` (serial, same reduction) ≥ 5× the uncached path on
//!   the paper grid;
//! * surface-driven `EventServer` ≥ 3× the direct phase-model path on a
//!   mixed long-context trace, with identical virtual-clock results.
//!
//! Emits `BENCH_hotpath.json` (override with `-- --out PATH`).
//!
//! PR 5 additions, all gated by `benches/baselines/BENCH_hotpath.json`:
//!
//! * a **counting allocator** wraps the system allocator so the bench can
//!   prove the unified decode event core allocates NOTHING per
//!   steady-state decode step — measured differentially (two runs
//!   identical except for extra pure-decode tokens; the allocation delta
//!   divided by the token delta must be ~0) at decode batch 1 and 4;
//! * a **B = 4 event-server speedup** (surface vs direct phase model,
//!   bit-identical clocks) — the batched hot path the de-allocation work
//!   targets;
//! * the **codesign warm-start gate**: shared `SurfaceFactory`s (one per
//!   page size) + the `SurfaceCache` must build the enlarged
//!   (designs × policies × batches × pool) grid's surfaces ≥ 3× faster
//!   than cold per-cell construction.
//!
//! PR 7 addition — the **decode fast-forward gate**: a 40k-token
//! long-decode trace must process ≥ 10× fewer queue events with
//! `EventServerConfig::fast_forward` on than stepped (it is >100× in
//! practice), with bit-identical virtual clocks and wall TPOT/TTFT, and
//! exact skipped-step conservation (`stepped_equivalent == stepped`).
//! The ratio is deterministic (no timing), so it hard-gates in smoke
//! runs too; the wall-clock speedup rides along as an advisory number.
//!
//! Run: `cargo bench --bench hotpath_kernel` (CI adds `-- --smoke`)

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use pd_swap::coordinator::{requests_from_trace, EventServer, EventServerConfig, Request};
use pd_swap::dse::{explore, explore_threads, explore_uncached, DseConfig, DseKernel};
use pd_swap::engines::{
    AcceleratorDesign, AttentionHosting, LatencySurface, PhaseModel, SurfaceCache,
    SurfaceFactory,
};
use pd_swap::fpga::KV260;
use pd_swap::model::{ModelShape, TraceSpec, BITNET_0_73B};
use pd_swap::reconfig::SwapPolicy;
use pd_swap::util::bench;
use pd_swap::util::cli::Args;
use pd_swap::util::json::Value;

/// Counting wrapper around the system allocator: every `alloc`,
/// `alloc_zeroed`, and growth `realloc` bumps one relaxed counter, so the
/// steady-state probe can assert "zero allocations per decode step"
/// differentially. Deallocation is not counted (frees are not the claim).
struct CountingAlloc;

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTING_ALLOC: CountingAlloc = CountingAlloc;

fn allocations() -> u64 {
    ALLOC_COUNT.load(Ordering::Relaxed)
}

/// Contexts probed for agreement: small, the paged-burst knee, the
/// prefill projection breakpoint neighbourhood, and the long tail.
fn probe_contexts(surface: &LatencySurface) -> Vec<usize> {
    let knee = surface.prefill_projection_breakpoint();
    let mut ls = vec![1, 2, 7, 8, 63, 64, 128, 512, 768, 2047, 2048];
    for d in [-1i64, 0, 1] {
        let l = (knee.round() as i64 + d).max(1) as usize;
        ls.push(l.min(BITNET_0_73B.max_seq));
    }
    ls.sort_unstable();
    ls.dedup();
    ls
}

fn rel_err(a: f64, b: f64) -> f64 {
    let scale = a.abs().max(b.abs());
    if scale == 0.0 {
        0.0
    } else {
        (a - b).abs() / scale
    }
}

/// Max relative deviation between surface and phase model over the paper
/// grid (subsampled), all probe contexts, page sizes, both hostings.
/// Returns `(single-stream worst, batched worst)` — the batched decode
/// closed forms (B in {1, 2, 4, 8}) are gated separately in
/// `BENCH_hotpath.json` so a regression names the kernel that moved.
fn agreement(cfg_dpr: &DseConfig, cfg_static: &DseConfig) -> (f64, f64) {
    let mut worst = 0.0f64;
    let mut worst_batched = 0.0f64;
    for cfg in [cfg_dpr, cfg_static] {
        let kernel = DseKernel::new(cfg);
        for (i, (t, p, d)) in cfg.grid().into_iter().enumerate() {
            if i % 7 != 0 {
                continue; // subsample: every 7th grid point
            }
            let fast = kernel.evaluate(t, p, d);
            let slow = pd_swap::dse::evaluate_grid_point(cfg, t, p, d);
            assert_eq!(fast.feasible, slow.feasible, "({t},{p},{d})");
            if !fast.feasible {
                continue;
            }
            worst = worst.max(rel_err(fast.objective, slow.objective));
            let surface = LatencySurface::new(&fast.design, &cfg.device, &cfg.shape, 32);
            let model = PhaseModel::new(fast.design.clone(), cfg.device.clone());
            for l in probe_contexts(&surface) {
                worst = worst.max(rel_err(
                    surface.prefill(l).total,
                    model.prefill(&cfg.shape, l).total,
                ));
                worst = worst.max(rel_err(
                    surface.decode_step(l).total,
                    model.decode_step(&cfg.shape, l).total,
                ));
                for pt in [1, 8, 32, 128] {
                    worst = worst.max(rel_err(
                        surface.decode_step_paged(l, pt).total,
                        model.decode_step_paged(&cfg.shape, l, pt).total,
                    ));
                }
                for b in [1usize, 2, 4, 8] {
                    let ctxs = vec![l; b];
                    worst_batched = worst_batched.max(rel_err(
                        surface.decode_step_batched(&ctxs).total,
                        model.decode_step_batched(&cfg.shape, &ctxs).total,
                    ));
                    worst_batched = worst_batched.max(rel_err(
                        surface.decode_step_batched_paged(&ctxs, 32).total,
                        model.decode_step_batched_paged(&cfg.shape, &ctxs, 32).total,
                    ));
                }
            }
        }
    }
    (worst, worst_batched)
}

/// Backlog-heavy mixed long-context trace: arrivals queue up behind the
/// long decodes, so the policy outlook (several model queries per event)
/// stays on the hot path — the serving regime the surface exists for.
fn mixed_workload() -> Vec<Request> {
    let spec = TraceSpec::mixed_long_context(40, 0.5, BITNET_0_73B.max_seq, 42);
    requests_from_trace(&spec.generate())
}

fn run_event_server_b(use_surface: bool, decode_batch: usize, wl: Vec<Request>) -> (f64, u64) {
    let mut cfg = EventServerConfig::pd_swap(
        BITNET_0_73B,
        KV260.clone(),
        SwapPolicy::hysteresis_default(),
    );
    cfg.use_surface = use_surface;
    cfg.decode_batch = decode_batch;
    let mut srv = EventServer::new(cfg).expect("config must program");
    srv.run(wl).expect("serving must not fail");
    (srv.clock(), srv.metrics.tokens_generated.get())
}

fn run_event_server(use_surface: bool, wl: Vec<Request>) -> (f64, u64) {
    run_event_server_b(use_surface, 1, wl)
}

/// Steady-state allocation probe: two runs identical except that the
/// second generates `gen_b − gen_a` extra tokens per request — pure
/// decode-step events (arrivals, prefills, swaps, and completions are
/// count-identical, and both runs saturate the metric reservoirs and the
/// event log, so their one-off allocations cancel). Returns allocations
/// per extra decode token, clamped at zero.
fn allocs_per_decode_token(decode_batch: usize, gen_a: usize, gen_b: usize) -> (f64, u64) {
    let workload = |gen: usize| -> Vec<Request> {
        (0..40).map(|i| Request::synthetic(i, 16, gen, 0.0)).collect()
    };
    let measure = |wl: Vec<Request>| -> (u64, u64) {
        // Eager: its decisions depend only on backlog COUNTS (never on
        // token-valued estimates), so the two runs' swap/prefill event
        // structure is identical and every non-decode allocation cancels
        // in the subtraction.
        //
        // `pd_swap()` leaves `trace: false` — this probe is the hard gate
        // that the tracing-DISABLED default (the TraceRecorder off path)
        // stays allocation-free on the decode hot path.
        let mut cfg =
            EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), SwapPolicy::Eager);
        cfg.decode_batch = decode_batch;
        let mut srv = EventServer::new(cfg).expect("config must program");
        let before = allocations();
        srv.run(wl).expect("serving must not fail");
        let after = allocations();
        (after - before, srv.metrics.tokens_generated.get())
    };
    let (alloc_a, tokens_a) = measure(workload(gen_a));
    let (alloc_b, tokens_b) = measure(workload(gen_b));
    assert!(tokens_b > tokens_a, "probe workloads must differ in decode volume");
    let extra_tokens = tokens_b - tokens_a;
    let extra_allocs = alloc_b.saturating_sub(alloc_a);
    (extra_allocs as f64 / extra_tokens as f64, extra_allocs)
}

fn main() {
    let args = Args::from_env();
    let out = args.get_or("out", "BENCH_hotpath.json");
    let smoke = args.flag("smoke");

    let cfg_dpr = DseConfig::paper_default(
        BITNET_0_73B,
        KV260.clone(),
        AttentionHosting::Reconfigurable,
    );
    let cfg_static =
        DseConfig::paper_default(BITNET_0_73B, KV260.clone(), AttentionHosting::StaticBoth);

    // -- agreement first: a fast wrong kernel is worthless -----------------
    bench::section("surface vs phase-model agreement");
    let (max_rel_err, batched_rel_err) = agreement(&cfg_dpr, &cfg_static);
    println!("max relative error across grid x contexts x pages: {max_rel_err:.3e}");
    println!("max relative error, batched decode (B in 1,2,4,8): {batched_rel_err:.3e}");
    assert!(
        max_rel_err <= 1e-9,
        "surface diverged from the phase model: {max_rel_err:.3e} > 1e-9"
    );
    assert!(
        batched_rel_err <= 1e-9,
        "batched surface diverged from the phase model: {batched_rel_err:.3e} > 1e-9"
    );

    // -- single-query microbench -------------------------------------------
    bench::section("analytic kernel microbench (decode_step_paged, 64 contexts)");
    let model = PhaseModel::new(AcceleratorDesign::pd_swap(), KV260.clone());
    let surface = LatencySurface::new(&AcceleratorDesign::pd_swap(), &KV260, &BITNET_0_73B, 32);
    let contexts: Vec<usize> = (1..=64).map(|i| i * 32).collect();
    let (mb_warm, mb_iters) = if smoke { (10, 200) } else { (100, 2_000) };
    let s_direct = bench::run("PhaseModel::decode_step_paged", mb_warm, mb_iters, || {
        for &l in &contexts {
            std::hint::black_box(model.decode_step_paged(&BITNET_0_73B, l, 32));
        }
    });
    println!("{s_direct}");
    let s_surface = bench::run("LatencySurface::decode_step_paged", mb_warm, mb_iters, || {
        for &l in &contexts {
            std::hint::black_box(surface.decode_step_paged(l, 32));
        }
    });
    println!("{s_surface}");
    let micro_speedup = s_direct.mean.as_secs_f64() / s_surface.mean.as_secs_f64();
    println!("microbench speedup: {micro_speedup:.1}x");

    // -- DSE grid ----------------------------------------------------------
    bench::section("dse::explore on the paper grid (cached kernel vs uncached)");
    let grid_points = cfg_dpr.grid().len();
    // Smoke keeps enough iterations that one noisy-neighbor interval on a
    // shared CI runner cannot sink the gated ratios below.
    let (dse_warm, dse_iters) = if smoke { (1, 6) } else { (2, 12) };
    let s_uncached = bench::run("explore (uncached reference, serial)", dse_warm, dse_iters, || {
        std::hint::black_box(explore_uncached(&cfg_dpr).unwrap());
    });
    println!("{s_uncached}");
    let s_cached = bench::run("explore (surface kernel, serial)", dse_warm, dse_iters, || {
        std::hint::black_box(explore_threads(&cfg_dpr, 1).unwrap());
    });
    println!("{s_cached}");
    let s_parallel = bench::run("explore (surface kernel, parallel)", dse_warm, dse_iters, || {
        std::hint::black_box(explore(&cfg_dpr).unwrap());
    });
    println!("{s_parallel}");
    // Same grid, same reduction: identical winners by construction.
    let a = explore_uncached(&cfg_dpr).unwrap();
    let b = explore_threads(&cfg_dpr, 4).unwrap();
    assert_eq!(a.best.design.name, b.best.design.name, "kernel changed the DSE winner");
    assert_eq!(a.feasible, b.feasible);
    assert!(rel_err(a.best.objective, b.best.objective) <= 1e-9);
    let dse_speedup = s_uncached.mean.as_secs_f64() / s_cached.mean.as_secs_f64();
    let dse_parallel_speedup = s_uncached.mean.as_secs_f64() / s_parallel.mean.as_secs_f64();
    println!(
        "kernel speedup {dse_speedup:.1}x (serial/serial), {dse_parallel_speedup:.1}x with threads"
    );
    // Full runs enforce the 5x acceptance bar; smoke (CI, short run on a
    // shared runner) enforces the satellite's hard invariant — cached
    // must never be slower than uncached — and leaves the 5x as an
    // advisory baseline gate until `--bless` calibrates it on a
    // reference machine (the repo's convention for unmeasured numbers).
    let dse_bar = if smoke { 1.0 } else { 5.0 };
    assert!(
        dse_speedup >= dse_bar,
        "DSE kernel speedup {dse_speedup:.2}x below the {dse_bar}x bar"
    );

    // -- EventServer mixed trace -------------------------------------------
    bench::section("EventServer mixed 2k-context trace (surface vs direct)");
    let wl = mixed_workload();
    let (clock_direct, tokens_direct) = run_event_server(false, wl.clone());
    let (clock_surface, tokens_surface) = run_event_server(true, wl.clone());
    assert_eq!(
        clock_direct.to_bits(),
        clock_surface.to_bits(),
        "virtual clocks must be bit-identical"
    );
    assert_eq!(tokens_direct, tokens_surface);
    println!(
        "{} requests, {} tokens, {:.1} s of virtual KV260 time",
        wl.len(),
        tokens_surface,
        clock_surface
    );
    let (ev_warm, ev_iters) = if smoke { (1, 5) } else { (1, 8) };
    let s_ev_direct = bench::run("EventServer (direct phase model)", ev_warm, ev_iters, || {
        std::hint::black_box(run_event_server(false, wl.clone()));
    });
    println!("{s_ev_direct}");
    let s_ev_surface = bench::run("EventServer (latency surface)", ev_warm, ev_iters, || {
        std::hint::black_box(run_event_server(true, wl.clone()));
    });
    println!("{s_ev_surface}");
    let ev_speedup = s_ev_direct.mean.as_secs_f64() / s_ev_surface.mean.as_secs_f64();
    println!("event-server speedup: {ev_speedup:.1}x");
    let ev_bar = if smoke { 1.0 } else { 3.0 };
    assert!(
        ev_speedup >= ev_bar,
        "EventServer surface speedup {ev_speedup:.2}x below the {ev_bar}x bar"
    );

    // -- EventServer at decode batch 4 (the multi-stream hot path) ---------
    bench::section("EventServer mixed trace at decode batch 4 (surface vs direct)");
    let (clock_d4, tokens_d4) = run_event_server_b(false, 4, wl.clone());
    let (clock_s4, tokens_s4) = run_event_server_b(true, 4, wl.clone());
    assert_eq!(
        clock_d4.to_bits(),
        clock_s4.to_bits(),
        "B=4 virtual clocks must be bit-identical"
    );
    assert_eq!(tokens_d4, tokens_s4);
    let s_ev4_direct = bench::run("EventServer B=4 (direct phase model)", ev_warm, ev_iters, || {
        std::hint::black_box(run_event_server_b(false, 4, wl.clone()));
    });
    println!("{s_ev4_direct}");
    let s_ev4_surface = bench::run("EventServer B=4 (latency surface)", ev_warm, ev_iters, || {
        std::hint::black_box(run_event_server_b(true, 4, wl.clone()));
    });
    println!("{s_ev4_surface}");
    let ev4_speedup = s_ev4_direct.mean.as_secs_f64() / s_ev4_surface.mean.as_secs_f64();
    println!("event-server speedup at B=4: {ev4_speedup:.1}x");
    assert!(
        ev4_speedup >= ev_bar,
        "B=4 EventServer surface speedup {ev4_speedup:.2}x below the {ev_bar}x bar"
    );

    // -- steady-state allocation probe -------------------------------------
    bench::section("steady-state allocations per decode step (counting allocator)");
    // 40 requests x 1700 vs 2000 generated tokens: both runs exceed the
    // 65536-sample metric reservoirs and the 16384-entry event log, so
    // every one-off allocation cancels and the delta isolates the pure
    // decode-step loop.
    let (allocs_b1, raw_b1) = allocs_per_decode_token(1, 1700, 2000);
    println!("B=1: {allocs_b1:.6} allocations per decode token ({raw_b1} raw over the delta)");
    let (allocs_b4, raw_b4) = allocs_per_decode_token(4, 1700, 2000);
    println!("B=4: {allocs_b4:.6} allocations per decode token ({raw_b4} raw over the delta)");
    // "Zero steady-state allocations": the amortized rate must be
    // indistinguishable from zero (1e-3 tolerates a stray one-off). With
    // tracing disabled (the default measured here) the TraceRecorder must
    // be bitwise inert — a regression in its `enabled` gating shows up
    // as per-token recorder allocations and fails these asserts.
    assert!(
        allocs_b1 <= 1e-3,
        "B=1 decode hot path allocates ({allocs_b1:.4}/token) — scratch reuse or the tracing-off gate regressed"
    );
    assert!(
        allocs_b4 <= 1e-3,
        "B=4 decode hot path allocates ({allocs_b4:.4}/token) — scratch reuse or the tracing-off gate regressed"
    );

    // -- decode fast-forward: 40k-token long-decode trace ------------------
    bench::section("event fast-forward (40k-token decode, folded vs stepped)");
    // The regime the analytic fast-forward exists for: one marathon
    // 40k-token generation (a 40960-context variant of the paper shape so
    // the sequence fits) plus a mid-run arrival that forces the fold to
    // stop at its horizon, re-enter the stepped path for the prefill +
    // two-stream stretch, and resume folding after the short request
    // drains. The pool is enlarged to hold the 40k-token KV (≈1.3k pages
    // at the default page size; the KV260 DDR budget would cap the
    // sequence otherwise).
    let shape_40k = ModelShape { max_seq: 40 * 1024, ..BITNET_0_73B };
    let ff_workload = || -> Vec<Request> {
        vec![
            Request::synthetic(0, 256, 40_000, 0.0),
            Request::synthetic(1, 128, 512, 30.0),
        ]
    };
    let run_ff = |fast_forward: bool| -> EventServer {
        let mut cfg = EventServerConfig::pd_swap(
            shape_40k,
            KV260.clone(),
            SwapPolicy::hysteresis_default(),
        );
        cfg.decode_batch = 4;
        cfg.fast_forward = fast_forward;
        cfg.pool = cfg.pool.clone().with_total_pages(4096);
        let mut srv = EventServer::new(cfg).expect("config must program");
        srv.run(ff_workload()).expect("serving must not fail");
        srv
    };
    let folded = run_ff(true);
    let stepped = run_ff(false);
    // Bit-identity is the admission ticket: a fast wrong fold is worthless.
    assert_eq!(
        folded.clock().to_bits(),
        stepped.clock().to_bits(),
        "fast-forward moved the virtual clock"
    );
    assert_eq!(
        folded.metrics.tokens_generated.get(),
        stepped.metrics.tokens_generated.get()
    );
    assert_eq!(
        folded.metrics.tpot.mean().to_bits(),
        stepped.metrics.tpot.mean().to_bits(),
        "fast-forward moved the wall TPOT"
    );
    assert_eq!(
        folded.metrics.ttft.mean().to_bits(),
        stepped.metrics.ttft.mean().to_bits()
    );
    let events_ff = folded.events_processed();
    let events_stepped = stepped.events_processed();
    // Skipped-step conservation: every fold stands in for exactly the
    // events the stepped run processed.
    assert_eq!(
        folded.fast_forward_stats().stepped_equivalent(events_ff),
        events_stepped,
        "fold accounting lost or invented events"
    );
    let events_skipped_ratio = events_stepped as f64 / events_ff.max(1) as f64;
    println!(
        "{} stepped events -> {} with fast-forward ({} folds, {} steps folded): {events_skipped_ratio:.1}x fewer events",
        events_stepped,
        events_ff,
        folded.fast_forward_stats().folds,
        folded.fast_forward_stats().steps,
    );
    // Hard gate (deterministic — no timing involved): the 40k-token trace
    // must shrink by at least 10x. In practice it is >100x.
    assert!(
        events_skipped_ratio >= 10.0,
        "fast-forward only cut events {events_skipped_ratio:.1}x (need >= 10x)"
    );
    let (ff_warm, ff_iters) = if smoke { (1, 3) } else { (1, 6) };
    let s_ff_stepped = bench::run("EventServer 40k decode (stepped)", ff_warm, ff_iters, || {
        std::hint::black_box(run_ff(false));
    });
    println!("{s_ff_stepped}");
    let s_ff_folded = bench::run("EventServer 40k decode (fast-forward)", ff_warm, ff_iters, || {
        std::hint::black_box(run_ff(true));
    });
    println!("{s_ff_folded}");
    let ff_speedup = s_ff_stepped.mean.as_secs_f64() / s_ff_folded.mean.as_secs_f64();
    println!("fast-forward wall-clock speedup: {ff_speedup:.1}x");

    // -- codesign warm-start: shared factories + cache vs cold per cell ----
    bench::section("codesign warm-start (factories + cache vs cold per-cell construction)");
    // The enlarged sweep's surface work: |designs| x |pages| distinct
    // surfaces, but |policies| x |batches| x |admission x eviction| cells
    // each. Cold pays a full construction per CELL; warm pays one factory
    // per page size plus pure-arithmetic cache fills per (design, page).
    let kernel = DseKernel::new(&cfg_dpr);
    let mut designs: Vec<AcceleratorDesign> = Vec::new();
    for (t, p, d) in cfg_dpr.grid() {
        if designs.len() >= 12 {
            break;
        }
        let point = kernel.evaluate(t, p, d);
        if point.feasible {
            designs.push(point.design);
        }
    }
    assert!(designs.len() >= 4, "need a few feasible designs to measure");
    let pages = [16usize, 32, 64];
    let cells_per_design_page = 3 * 2 * 2; // policies x batches x (admission x eviction)
    let (ws_warm, ws_iters) = if smoke { (1, 5) } else { (2, 10) };
    let s_cold = bench::run("cold: LatencySurface::new per cell", ws_warm, ws_iters, || {
        for d in &designs {
            for &pt in &pages {
                for _ in 0..cells_per_design_page {
                    std::hint::black_box(LatencySurface::new(d, &KV260, &BITNET_0_73B, pt));
                }
            }
        }
    });
    println!("{s_cold}");
    let s_warm = bench::run("warm: per-page factories + SurfaceCache", ws_warm, ws_iters, || {
        let factories: Vec<SurfaceFactory> = pages
            .iter()
            .map(|&pt| SurfaceFactory::new(&KV260, &BITNET_0_73B, pt))
            .collect();
        let mut cache = SurfaceCache::new();
        for d in &designs {
            for f in &factories {
                for _ in 0..cells_per_design_page {
                    std::hint::black_box(cache.get_with(f, d));
                }
            }
        }
    });
    println!("{s_warm}");
    let warm_speedup = s_cold.mean.as_secs_f64() / s_warm.mean.as_secs_f64();
    println!(
        "warm-start speedup over {} designs x {} pages x {} cells: {warm_speedup:.1}x",
        designs.len(),
        pages.len(),
        cells_per_design_page
    );
    let ws_bar = if smoke { 1.5 } else { 3.0 };
    assert!(
        warm_speedup >= ws_bar,
        "codesign warm-start speedup {warm_speedup:.2}x below the {ws_bar}x bar"
    );

    let report = Value::Obj(vec![
        ("bench".into(), Value::Str("hotpath_kernel".into())),
        (
            "agreement".into(),
            Value::Obj(vec![
                ("max_rel_err".into(), Value::Num(max_rel_err)),
                ("batched_max_rel_err".into(), Value::Num(batched_rel_err)),
            ]),
        ),
        (
            "microbench".into(),
            Value::Obj(vec![
                ("uncached_us_per_64_calls".into(), Value::Num(s_direct.mean_ms() * 1e3)),
                ("cached_us_per_64_calls".into(), Value::Num(s_surface.mean_ms() * 1e3)),
                ("speedup".into(), Value::Num(micro_speedup)),
            ]),
        ),
        (
            "dse_explore".into(),
            Value::Obj(vec![
                ("grid_points".into(), Value::Num(grid_points as f64)),
                ("feasible".into(), Value::Num(a.feasible as f64)),
                ("uncached_ms".into(), Value::Num(s_uncached.mean_ms())),
                ("cached_serial_ms".into(), Value::Num(s_cached.mean_ms())),
                ("cached_parallel_ms".into(), Value::Num(s_parallel.mean_ms())),
                ("speedup".into(), Value::Num(dse_speedup)),
                ("parallel_speedup".into(), Value::Num(dse_parallel_speedup)),
            ]),
        ),
        (
            "event_server".into(),
            Value::Obj(vec![
                ("requests".into(), Value::Num(wl.len() as f64)),
                ("tokens".into(), Value::Num(tokens_surface as f64)),
                ("virtual_clock_s".into(), Value::Num(clock_surface)),
                ("uncached_ms".into(), Value::Num(s_ev_direct.mean_ms())),
                ("cached_ms".into(), Value::Num(s_ev_surface.mean_ms())),
                ("speedup".into(), Value::Num(ev_speedup)),
                ("allocs_per_decode_token_b1".into(), Value::Num(allocs_b1)),
                ("allocs_per_decode_token_b4".into(), Value::Num(allocs_b4)),
            ]),
        ),
        (
            "event_server_b4".into(),
            Value::Obj(vec![
                ("tokens".into(), Value::Num(tokens_s4 as f64)),
                ("virtual_clock_s".into(), Value::Num(clock_s4)),
                ("uncached_ms".into(), Value::Num(s_ev4_direct.mean_ms())),
                ("cached_ms".into(), Value::Num(s_ev4_surface.mean_ms())),
                ("speedup".into(), Value::Num(ev4_speedup)),
            ]),
        ),
        (
            "event_fast_forward".into(),
            Value::Obj(vec![
                ("tokens".into(), Value::Num(folded.metrics.tokens_generated.get() as f64)),
                ("virtual_clock_s".into(), Value::Num(folded.clock())),
                ("events_stepped".into(), Value::Num(events_stepped as f64)),
                ("events_ff".into(), Value::Num(events_ff as f64)),
                ("events_skipped_ratio".into(), Value::Num(events_skipped_ratio)),
                ("stepped_ms".into(), Value::Num(s_ff_stepped.mean_ms())),
                ("ff_ms".into(), Value::Num(s_ff_folded.mean_ms())),
                ("speedup".into(), Value::Num(ff_speedup)),
            ]),
        ),
        (
            "codesign_warmstart".into(),
            Value::Obj(vec![
                ("designs".into(), Value::Num(designs.len() as f64)),
                ("page_sizes".into(), Value::Num(pages.len() as f64)),
                (
                    "cells_per_design_page".into(),
                    Value::Num(cells_per_design_page as f64),
                ),
                ("cold_ms".into(), Value::Num(s_cold.mean_ms())),
                ("warm_ms".into(), Value::Num(s_warm.mean_ms())),
                ("speedup".into(), Value::Num(warm_speedup)),
            ]),
        ),
    ]);
    match bench::write_json_report(out, &report) {
        Ok(p) => println!("\nwrote {p}"),
        Err(e) => eprintln!("\ncould not write {out}: {e}"),
    }
}
