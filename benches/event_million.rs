//! Bench: the million-request event core — interference-aware gap
//! folding, streaming arrivals, and O(resident) memory, measured
//! end-to-end on the decode-heavy `million` trace preset.
//!
//! Three claims, each gated by `benches/baselines/BENCH_event_million.json`:
//!
//! 1. **Folding**: on the decode-heavy segment (batch 1, one residency
//!    slot, per-layer prefill markers off — the swap-adjacent idle-gap
//!    regime the interference lattice targets) the fold must process
//!    **≥ 50× fewer queue events** than the stepped engine would
//!    (`events_skipped_ratio`, a deterministic count ratio on the
//!    virtual clock — hard even in `--smoke`). The multi-stream shape
//!    (B = 4, four residency slots) gates hard at ≥ 10× with the 50×
//!    bar advisory. The ratio is read off the fold's own conservation
//!    law (`stepped_equivalent / events_processed`), which a real
//!    stepped run validates exactly at small scale first.
//! 2. **Bit-identity**: streamed-vs-materialized and folded-vs-stepped
//!    runs are asserted fingerprint-identical (clock, counters,
//!    histogram means, outcome order) before any number is reported —
//!    a fast wrong core is worthless.
//! 3. **O(resident) memory**: a byte-tracking allocator measures the
//!    *peak* heap growth of a streamed run at N and at 2N requests
//!    (smoke: 10k/20k; full: 100k/200k). Peak must be independent of
//!    request count — ratio ≤ 1.02 in full runs, where every metric
//!    reservoir saturates; within an absolute +600 KiB slack in smoke,
//!    where the 65536-sample TTFT/e2e reservoirs are still filling —
//!    and steady-state allocations must stay O(1) per request
//!    (≤ 32 allocs/request over the differential).
//!
//! Requests/second and events/second ride along as advisory
//! host-relative numbers (the repo convention until blessed on a
//! reference machine).
//!
//! Run: `cargo bench --bench event_million` (CI adds `-- --smoke`)

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use pd_swap::coordinator::{
    requests_from_stream, requests_from_trace, EventServer, EventServerConfig,
};
use pd_swap::fpga::KV260;
use pd_swap::model::{TraceSpec, BITNET_0_73B};
use pd_swap::reconfig::SwapPolicy;
use pd_swap::util::bench;
use pd_swap::util::cli::Args;
use pd_swap::util::json::Value;

/// Byte-tracking wrapper around the system allocator: live bytes and the
/// high-water mark, plus an allocation counter. `realloc` tracks the
/// size delta, so Vec growth is charged at its true cost. Relaxed
/// ordering is fine — the bench is single-threaded.
struct PeakAlloc;

static LIVE: AtomicU64 = AtomicU64::new(0);
static PEAK: AtomicU64 = AtomicU64::new(0);
static COUNT: AtomicU64 = AtomicU64::new(0);

fn charge(n: usize) {
    let live = LIVE.fetch_add(n as u64, Ordering::Relaxed) + n as u64;
    PEAK.fetch_max(live, Ordering::Relaxed);
}

fn credit(n: usize) {
    LIVE.fetch_sub(n as u64, Ordering::Relaxed);
}

unsafe impl GlobalAlloc for PeakAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        COUNT.fetch_add(1, Ordering::Relaxed);
        let p = System.alloc(layout);
        if !p.is_null() {
            charge(layout.size());
        }
        p
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        COUNT.fetch_add(1, Ordering::Relaxed);
        let p = System.alloc_zeroed(layout);
        if !p.is_null() {
            charge(layout.size());
        }
        p
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        COUNT.fetch_add(1, Ordering::Relaxed);
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            if new_size >= layout.size() {
                charge(new_size - layout.size());
            } else {
                credit(layout.size() - new_size);
            }
        }
        p
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        credit(layout.size());
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static PEAK_ALLOC: PeakAlloc = PeakAlloc;

/// The shared config for every run in this bench: Eager policy (decision
/// structure independent of token-valued estimates), million-trace
/// serving with the per-layer prefill markers off.
fn base_cfg(decode_batch: usize, max_residents: usize) -> EventServerConfig {
    let mut cfg = EventServerConfig::pd_swap(BITNET_0_73B, KV260.clone(), SwapPolicy::Eager);
    cfg.decode_batch = decode_batch;
    cfg.max_residents = max_residents;
    cfg.prefill_layer_events = false;
    cfg
}

/// Everything the bit-identity pins cover, in one comparable string.
/// (The diagnostic event log and Chrome trace are outside the contract.)
fn fingerprint(s: &EventServer) -> String {
    use std::fmt::Write as _;
    let m = &s.metrics;
    let mut out = String::new();
    let _ = writeln!(out, "clock {:x}", s.clock().to_bits());
    let _ = writeln!(
        out,
        "counts {} {} {} {} {}",
        m.requests_completed.get(),
        m.tokens_generated.get(),
        m.reconfigurations.get(),
        m.kv_evictions.get(),
        m.kv_admissions_capped.get(),
    );
    for (name, h) in [("tpot", &m.tpot), ("ttft", &m.ttft), ("e2e", &m.e2e)] {
        let _ = writeln!(
            out,
            "{name} {} {:x} {:x} {:x}",
            h.count(),
            h.mean().to_bits(),
            h.min().to_bits(),
            h.max().to_bits(),
        );
    }
    for o in &s.outcomes {
        let _ = writeln!(
            out,
            "outcome {} {:x} {:x} {:x}",
            o.id,
            o.ttft.to_bits(),
            o.e2e.to_bits(),
            o.mean_tpot.to_bits(),
        );
    }
    let _ = writeln!(out, "dropped {}", s.outcomes.dropped());
    out
}

/// One streamed million-trace run under the byte tracker. Returns
/// `(peak_heap_growth_bytes, allocations, wall_s, server)`.
fn measured_streamed_run(n: usize, seed: u64) -> (u64, u64, f64, EventServer) {
    let spec = TraceSpec::million(n, seed);
    let mut cfg = base_cfg(1, 8);
    cfg.outcome_retain = 4096;
    cfg.log_tail = Some(4096);
    let mut srv = EventServer::new(cfg).expect("config must program");
    // Settle the tracker on the post-construction heap, then measure the
    // run's growth above it.
    let base = LIVE.load(Ordering::Relaxed);
    PEAK.store(base, Ordering::Relaxed);
    let allocs_before = COUNT.load(Ordering::Relaxed);
    let t0 = Instant::now();
    srv.run_streamed(requests_from_stream(spec.stream()), 1024)
        .expect("serving must not fail");
    let wall = t0.elapsed().as_secs_f64();
    let peak = PEAK.load(Ordering::Relaxed).saturating_sub(base);
    let allocs = COUNT.load(Ordering::Relaxed) - allocs_before;
    (peak, allocs, wall, srv)
}

fn main() {
    let args = Args::from_env();
    let out = args.get_or("out", "BENCH_event_million.json");
    let smoke = args.flag("smoke");

    // -- bit-identity first: a fast wrong core is worthless ----------------
    bench::section("bit-identity pins (streamed = materialized, folded = stepped)");
    let pin_spec = TraceSpec::million(500, 7);
    let pin_reqs = requests_from_trace(&pin_spec.generate());
    let run_pin = |fast_forward: bool, streamed: bool| -> EventServer {
        let mut cfg = base_cfg(1, 1);
        cfg.fast_forward = fast_forward;
        let mut srv = EventServer::new(cfg).expect("config must program");
        if streamed {
            srv.run_streamed(requests_from_stream(pin_spec.stream()), 64)
                .expect("serving must not fail");
        } else {
            srv.run(pin_reqs.clone()).expect("serving must not fail");
        }
        srv
    };
    let folded = run_pin(true, false);
    let streamed = run_pin(true, true);
    let stepped = run_pin(false, false);
    assert_eq!(
        fingerprint(&folded),
        fingerprint(&streamed),
        "streamed run diverged from materialized"
    );
    assert_eq!(
        fingerprint(&folded),
        fingerprint(&stepped),
        "fold diverged from the stepped engine"
    );
    // Conservation, validated against a REAL stepped run: every folded
    // token-step stands in for exactly one queue event, and absorbed
    // arrivals are real events on both sides. This is what licenses
    // reading the large-run ratios off `stepped_equivalent` below.
    assert_eq!(
        folded.fast_forward_stats().stepped_equivalent(folded.events_processed()),
        stepped.events_processed(),
        "fold accounting lost or invented events"
    );
    assert!(
        folded.fast_forward_stats().absorbed_arrivals > 0,
        "the saturated million trace must absorb dormant arrivals mid-fold"
    );
    println!(
        "500-request pin: {} stepped events -> {} folded ({} arrivals absorbed mid-fold), fingerprints identical",
        stepped.events_processed(),
        folded.events_processed(),
        folded.fast_forward_stats().absorbed_arrivals,
    );

    // -- events-skipped ratio, decode-heavy segment ------------------------
    bench::section("events-skipped ratio (million trace, 2000 requests)");
    let ratio_of = |srv: &EventServer| -> f64 {
        let processed = srv.events_processed();
        srv.fast_forward_stats().stepped_equivalent(processed) as f64 / processed.max(1) as f64
    };
    // Decode-heavy segment: batch 1, a single residency slot, markers
    // off — every mid-decode arrival is dormant, so folds run wall to
    // wall through the idle gaps.
    let ratio_spec = TraceSpec::million(2000, 11);
    let ratio_reqs = requests_from_trace(&ratio_spec.generate());
    let run_ratio = |batch: usize, residents: usize| -> EventServer {
        let mut srv = EventServer::new(base_cfg(batch, residents)).expect("config must program");
        srv.run(ratio_reqs.clone()).expect("serving must not fail");
        srv
    };
    let decode_heavy = run_ratio(1, 1);
    let ratio_decode_heavy = ratio_of(&decode_heavy);
    println!(
        "B=1, one residency slot: {:.1}x fewer events ({} folds, {} arrivals absorbed)",
        ratio_decode_heavy,
        decode_heavy.fast_forward_stats().folds,
        decode_heavy.fast_forward_stats().absorbed_arrivals,
    );
    assert!(
        ratio_decode_heavy >= 50.0,
        "decode-heavy events-skipped ratio {ratio_decode_heavy:.1}x below the hard 50x bar"
    );
    let b4 = run_ratio(4, 4);
    let ratio_b4 = ratio_of(&b4);
    println!("B=4, four residency slots: {ratio_b4:.1}x fewer events");
    assert!(
        ratio_b4 >= 10.0,
        "B=4 events-skipped ratio {ratio_b4:.1}x below the hard 10x bar"
    );

    // -- O(resident) memory: peak independence + allocs per request --------
    bench::section("peak-memory independence (streamed, N vs 2N requests)");
    let n = if smoke { 10_000 } else { 100_000 };
    let (peak_1x, allocs_1x, wall_1x, srv_1x) = measured_streamed_run(n, 1);
    let (peak_2x, allocs_2x, wall_2x, srv_2x) = measured_streamed_run(2 * n, 1);
    assert_eq!(srv_1x.metrics.requests_completed.get(), n as u64);
    assert_eq!(srv_2x.metrics.requests_completed.get(), 2 * n as u64);
    let peak_ratio = peak_2x as f64 / peak_1x.max(1) as f64;
    let allocs_per_request = allocs_2x.saturating_sub(allocs_1x) as f64 / n as f64;
    println!(
        "peak heap growth: {:.2} MiB at {n} requests, {:.2} MiB at {} (ratio {peak_ratio:.3})",
        peak_1x as f64 / (1 << 20) as f64,
        peak_2x as f64 / (1 << 20) as f64,
        2 * n,
    );
    println!("steady-state allocations: {allocs_per_request:.2} per request over the differential");
    // Full runs saturate every 65536-sample reservoir, so the peak must
    // be flat (ratio <= 1.02). Smoke runs are still filling the
    // per-request TTFT/e2e reservoirs, whose Vec-doubling growth
    // (2 histograms x 16384 extra f64 samples ~ 262 KiB) is the only
    // N-dependent term left — so smoke gates an absolute slack instead
    // of a ratio: anything O(requests) (outcome Vec, materialized
    // arrival queue, unbounded log) adds megabytes, not KiB. The
    // baseline carries the mode-independent ratio bar 1.5; these
    // asserts are the tight ones.
    if smoke {
        let slack = 600 * 1024;
        assert!(
            peak_2x <= peak_1x + slack,
            "peak heap grew with request count: +{} bytes > {slack} slack — an O(requests) structure is back",
            peak_2x.saturating_sub(peak_1x)
        );
    } else {
        assert!(
            peak_ratio <= 1.02,
            "peak heap grew with request count: ratio {peak_ratio:.3} > 1.02 at saturated reservoirs — an O(requests) structure is back"
        );
    }
    assert!(
        allocs_per_request <= 32.0,
        "steady-state allocations {allocs_per_request:.1}/request — the per-request path is allocating"
    );

    // -- throughput (advisory, host-relative) ------------------------------
    bench::section("throughput (advisory until blessed)");
    let requests_per_sec = (2 * n) as f64 / wall_2x.max(1e-9);
    let events_per_sec = srv_2x.events_processed() as f64 / wall_2x.max(1e-9);
    let folded_steps_per_sec = srv_2x.fast_forward_stats().steps as f64 / wall_2x.max(1e-9);
    println!(
        "{} requests in {wall_2x:.2}s: {requests_per_sec:.0} requests/s, {events_per_sec:.0} events/s, {folded_steps_per_sec:.0} folded token-steps/s",
        2 * n
    );
    println!(
        "(N-run: {n} requests in {wall_1x:.2}s; {:.1}x fewer events than stepped at 2N)",
        ratio_of(&srv_2x)
    );

    let report = Value::Obj(vec![
        ("bench".into(), Value::Str("event_million".into())),
        ("smoke".into(), Value::Num(u8::from(smoke) as f64)),
        (
            "decode_heavy".into(),
            Value::Obj(vec![
                ("requests".into(), Value::Num(ratio_reqs.len() as f64)),
                ("events_processed".into(), Value::Num(decode_heavy.events_processed() as f64)),
                ("events_skipped_ratio".into(), Value::Num(ratio_decode_heavy)),
                (
                    "absorbed_arrivals".into(),
                    Value::Num(decode_heavy.fast_forward_stats().absorbed_arrivals as f64),
                ),
            ]),
        ),
        (
            "b4".into(),
            Value::Obj(vec![
                ("events_processed".into(), Value::Num(b4.events_processed() as f64)),
                ("events_skipped_ratio".into(), Value::Num(ratio_b4)),
            ]),
        ),
        (
            "peak".into(),
            Value::Obj(vec![
                ("requests_1x".into(), Value::Num(n as f64)),
                ("peak_bytes_1x".into(), Value::Num(peak_1x as f64)),
                ("peak_bytes_2x".into(), Value::Num(peak_2x as f64)),
                ("ratio".into(), Value::Num(peak_ratio)),
                ("allocs_per_request".into(), Value::Num(allocs_per_request)),
            ]),
        ),
        (
            "throughput".into(),
            Value::Obj(vec![
                ("requests_per_sec".into(), Value::Num(requests_per_sec)),
                ("events_per_sec".into(), Value::Num(events_per_sec)),
                ("folded_steps_per_sec".into(), Value::Num(folded_steps_per_sec)),
                ("wall_s_2x".into(), Value::Num(wall_2x)),
            ]),
        ),
    ]);
    match bench::write_json_report(out, &report) {
        Ok(p) => println!("\nwrote {p}"),
        Err(e) => eprintln!("\ncould not write {out}: {e}"),
    }
}
