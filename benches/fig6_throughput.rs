//! Bench: regenerate Fig. 6 (decode throughput + TTFT vs context length)
//! and check the paper's endpoints.
//!
//! Run: `cargo bench --bench fig6_throughput`

use pd_swap::eval::{run_fig6, Fig6Point};
use pd_swap::util::bench;

fn main() {
    bench::section("Fig. 6 — decoding throughput (a) and prefill TTFT (b)");
    let pts = run_fig6(pd_swap::eval::fig6::LENGTHS);

    let at = |l: usize| -> &Fig6Point { pts.iter().find(|p| p.l == l).unwrap() };
    bench::section("paper vs measured");
    println!(
        "speedup @64    measured {:4.2}x  paper 1.11x  delta {:+5.1}%",
        at(64).decode_speedup,
        (at(64).decode_speedup / 1.11 - 1.0) * 100.0
    );
    println!(
        "speedup @2048  measured {:4.2}x  paper 2.02x  delta {:+5.1}%",
        at(2048).decode_speedup,
        (at(2048).decode_speedup / 2.02 - 1.0) * 100.0
    );
    println!(
        "PD TTFT @768   measured {:5.2} s  paper 8.80 s  delta {:+5.1}%",
        at(768).pd_ttft,
        (at(768).pd_ttft / 8.80 - 1.0) * 100.0
    );
    println!(
        "TeLLMe TTFT @768 measured {:5.2} s  paper 11.10 s  delta {:+5.1}%",
        at(768).te_ttft,
        (at(768).te_ttft / 11.10 - 1.0) * 100.0
    );
    println!(
        "PD decode @2048 measured {:4.1} tok/s  paper '>10'",
        at(2048).pd_decode_tks
    );
    println!(
        "TeLLMe decode @2048 measured {:4.1} tok/s  paper ~5",
        at(2048).te_decode_tks
    );

    bench::section("timing");
    let s = bench::run("fig6 full series (8 lengths, 2 designs)", 5, 100, || {
        std::hint::black_box(pd_swap::eval::fig6::series(pd_swap::eval::fig6::LENGTHS));
    });
    println!("{s}");
}
