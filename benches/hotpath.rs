//! Bench: the L3 hot paths — simulator step costs, the serving loop, and
//! (when artifacts exist) the real PJRT prefill/decode calls.
//!
//! This is the §Perf measurement harness: every optimization in
//! EXPERIMENTS.md §Perf quotes numbers from here.
//!
//! Run: `cargo bench --bench hotpath`

use pd_swap::coordinator::{generate_workload, SimServer, SimServerConfig, WorkloadConfig};
use pd_swap::engines::{AcceleratorDesign, PhaseModel};
use pd_swap::fpga::KV260;
use pd_swap::model::BITNET_0_73B;
use pd_swap::util::bench;

fn main() {
    let shape = BITNET_0_73B;

    bench::section("simulator primitives");
    let model = PhaseModel::new(AcceleratorDesign::pd_swap(), KV260.clone());
    let s = bench::run("decode_step latency query", 100, 10_000, || {
        std::hint::black_box(model.decode_step(&shape, 1024));
    });
    println!("{s}");
    let s = bench::run("prefill latency query", 100, 10_000, || {
        std::hint::black_box(model.prefill(&shape, 768));
    });
    println!("{s}");
    let s = bench::run("floorplan + validate", 10, 2_000, || {
        let d = AcceleratorDesign::pd_swap();
        std::hint::black_box(d.region_plan().unwrap().validate(&KV260).unwrap());
    });
    println!("{s}");

    bench::section("simulated serving loop (16 requests, BitNet 0.73B)");
    let wl = generate_workload(&WorkloadConfig { n_requests: 16, ..Default::default() });
    let s = bench::run("SimServer end-to-end", 2, 20, || {
        let mut srv =
            SimServer::new(SimServerConfig::pd_swap(shape, KV260.clone())).unwrap();
        srv.run(wl.clone()).unwrap();
        std::hint::black_box(srv.metrics.tokens_generated.get());
    });
    println!("{s}");
    // Simulated-time / wall-time ratio: how much faster than real time the
    // simulator runs (the sim covers minutes of KV260 time).
    {
        let mut srv = SimServer::new(SimServerConfig::pd_swap(shape, KV260.clone())).unwrap();
        let t0 = std::time::Instant::now();
        srv.run(wl.clone()).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "simulated {:.1} s of KV260 time in {:.3} s wall ({:.0}x real time)",
            srv.clock(),
            wall,
            srv.clock() / wall
        );
    }

    pjrt_section();
}

#[cfg(feature = "pjrt")]
fn pjrt_section() {
    use std::time::Duration;

    use pd_swap::runtime::InferenceEngine;

    bench::section("PJRT hot path (artifacts/test — skip if absent)");
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/test");
    if dir.join("manifest.json").exists() {
        let engine = InferenceEngine::load(&dir).expect("engine");
        let prompt: Vec<i32> = (1..=5).collect();
        let s = bench::run_for("prefill (test model, bucket 8)", Duration::from_secs(3), || {
            std::hint::black_box(engine.prefill(&prompt).unwrap());
        });
        println!("{s}");
        let pre = engine.prefill(&prompt).unwrap();
        let mut cache = Some(pre.cache);
        let s = bench::run_for("decode step (test model)", Duration::from_secs(3), || {
            let c = cache.take().unwrap();
            // Re-decode at the same position each iteration: take the new
            // cache but reset its logical length so it never fills.
            let (_, mut nc) = engine.decode(7, c).unwrap();
            nc.len = 5;
            cache = Some(nc);
        });
        println!("{s}");
        println!(
            "runtime stats: {} prefills ({:.2} ms avg), {} decodes ({:.2} ms avg)",
            engine.stats.prefill_calls.load(std::sync::atomic::Ordering::Relaxed),
            engine.stats.avg_prefill_ms(),
            engine.stats.decode_calls.load(std::sync::atomic::Ordering::Relaxed),
            engine.stats.avg_decode_ms(),
        );
    } else {
        println!("artifacts/test not built — run `make artifacts` for PJRT numbers");
    }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_section() {
    bench::section("PJRT hot path");
    println!("built without the `pjrt` feature — rebuild with --features pjrt for PJRT numbers");
}
