//! Bench: DPR swap-scheduling policies under continuous mixed traffic on
//! the event-driven serving core — `EagerSwap` (the paper's per-request
//! flow) vs. `HysteresisSwap` and `LookaheadSwap` (our serving
//! extensions), on a long-context model (peak context ≥ 16k tokens).
//!
//! The trace mixes a Poisson stream of short interactive prompts with
//! periodic long-context analytics requests whose prompt+generation
//! reaches 16k tokens. Under this traffic, eager swapping yields the
//! fabric to every newcomer: each arrival interrupts the long decode for
//! a full PCAP round trip plus the interposed prefill, all of which
//! lands in the resident requests' inter-token gaps. Hysteresis and
//! lookahead batch those interruptions, so their wall-TPOT decode
//! throughput must come out ahead — that ordering is this bench's
//! acceptance assertion, and the committed baseline gates it in CI.
//!
//! All reported numbers are *simulated KV260* values on a deterministic
//! virtual clock — identical on every machine and run. Only the optional
//! wall-clock section (skipped with `-- --smoke`) measures host time.
//!
//! Emits `BENCH_swap_policy.json` (override with `-- --out PATH`).
//!
//! Run: `cargo bench --bench swap_policy`

use pd_swap::coordinator::{EventServer, EventServerConfig, Request};
use pd_swap::fpga::KV260;
use pd_swap::model::{ModelShape, Precision, TraceSpec};
use pd_swap::reconfig::SwapPolicy;
use pd_swap::util::bench;
use pd_swap::util::cli::Args;
use pd_swap::util::json::Value;

/// e2e-100m widened to a 16k context window — small enough that several
/// long contexts fit the KV260's DDR KV budget, big enough that decode at
/// the context tail is deeply memory-bound.
const LONG_CTX_16K: ModelShape = ModelShape {
    name: "e2e-100m-16k",
    n_layers: 10,
    d_model: 768,
    n_heads: 12,
    d_ff: 3072,
    vocab: 8192,
    max_seq: 16 * 1024,
    kv_precision: Precision::Fp16,
};

/// Long-context analytics class: peak context 14592 + 1792 = 16384.
const LONG_PROMPT: usize = 14 * 1024 + 256;
const LONG_GEN: usize = 1792;
const N_LONG: usize = 3;
const LONG_SPACING_S: f64 = 420.0;

/// Poisson short-interactive stream.
const N_SHORT: usize = 36;
const SHORT_RATE: f64 = 0.08;
const SEED: u64 = 42;

/// Mixed trace: deterministic long-context stream + Poisson shorts.
fn mixed_trace() -> Vec<Request> {
    let shorts = TraceSpec::interactive(N_SHORT, SHORT_RATE, SEED).generate();
    let mut entries: Vec<(f64, usize, usize)> = shorts
        .iter()
        .map(|e| (e.arrival, e.prompt_len, e.gen_len))
        .collect();
    for i in 0..N_LONG {
        entries.push((i as f64 * LONG_SPACING_S, LONG_PROMPT, LONG_GEN));
    }
    entries.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    entries
        .iter()
        .enumerate()
        .map(|(i, &(t, p, g))| Request::synthetic(i as u64, p, g, t))
        .collect()
}

struct PolicyRun {
    name: &'static str,
    /// 1 / mean wall inter-token gap: swap round trips and interposed
    /// prefills land in these gaps, so this is the policy-sensitive
    /// decode throughput.
    decode_tps: f64,
    /// Total tokens over the serving makespan.
    makespan_tps: f64,
    makespan_s: f64,
    tokens: u64,
    swaps: u64,
    swaps_to_prefill: u64,
    exposed_total_s: f64,
    ttft: Value,
    tpot: Value,
}

fn run_policy(policy: SwapPolicy, wl: Vec<Request>) -> PolicyRun {
    let n = wl.len() as u64;
    let mut srv =
        EventServer::new(EventServerConfig::pd_swap(LONG_CTX_16K, KV260.clone(), policy))
            .expect("config must program");
    srv.run(wl).expect("serving must not fail");
    assert_eq!(srv.metrics.requests_completed.get(), n, "all requests complete");
    srv.pool().check_invariants().expect("pool accounting balances at drain");
    let m = &srv.metrics;
    let exposed_total_s = m.reconfig_exposed.mean() * m.reconfig_exposed.count() as f64;
    PolicyRun {
        name: policy.name(),
        decode_tps: m.decode_throughput(),
        makespan_tps: m.tokens_generated.get() as f64 / srv.clock().max(1e-12),
        makespan_s: srv.clock(),
        tokens: m.tokens_generated.get(),
        swaps: m.reconfigurations.get(),
        swaps_to_prefill: m.swaps_to_prefill.get(),
        exposed_total_s,
        ttft: m.ttft.summary_json(),
        tpot: m.tpot.summary_json(),
    }
}

fn run_json(r: &PolicyRun) -> Value {
    Value::Obj(vec![
        ("decode_tokens_per_sec".into(), Value::Num(r.decode_tps)),
        ("makespan_tokens_per_sec".into(), Value::Num(r.makespan_tps)),
        ("makespan_s".into(), Value::Num(r.makespan_s)),
        ("tokens".into(), Value::Num(r.tokens as f64)),
        ("swaps".into(), Value::Num(r.swaps as f64)),
        ("swaps_to_prefill".into(), Value::Num(r.swaps_to_prefill as f64)),
        ("reconfig_exposed_total_s".into(), Value::Num(r.exposed_total_s)),
        ("ttft".into(), r.ttft.clone()),
        ("tpot".into(), r.tpot.clone()),
    ])
}

fn main() {
    let args = Args::from_env();
    let out = args.get_or("out", "BENCH_swap_policy.json");
    let smoke = args.flag("smoke");

    let wl = mixed_trace();
    let total_tokens: usize = wl.iter().map(|r| r.max_new_tokens).sum();
    bench::section("swap-scheduling policies under mixed traffic");
    println!(
        "model {}: peak context {} ({} long x {}+{} tok, {} short Poisson @ {:.2}/s), {} gen tokens total",
        LONG_CTX_16K.name,
        LONG_PROMPT + LONG_GEN,
        N_LONG,
        LONG_PROMPT,
        LONG_GEN,
        N_SHORT,
        SHORT_RATE,
        total_tokens,
    );

    let runs: Vec<PolicyRun> = [
        SwapPolicy::Eager,
        SwapPolicy::hysteresis_default(),
        SwapPolicy::lookahead_default(),
    ]
    .into_iter()
    .map(|p| run_policy(p, wl.clone()))
    .collect();

    println!(
        "{:<12} {:>12} {:>12} {:>7} {:>12} {:>12} {:>12}",
        "policy", "decode t/s", "e2e t/s", "swaps", "exposed s", "ttft p95 s", "makespan s"
    );
    for r in &runs {
        println!(
            "{:<12} {:>12.2} {:>12.2} {:>7} {:>12.2} {:>12.1} {:>12.1}",
            r.name,
            r.decode_tps,
            r.makespan_tps,
            r.swaps,
            r.exposed_total_s,
            r.ttft.get("p95_s").and_then(Value::as_f64).unwrap_or(0.0),
            r.makespan_s,
        );
    }

    let (eager, hyst, look) = (&runs[0], &runs[1], &runs[2]);
    // Same trace, same total work: tokens must agree across policies.
    assert_eq!(eager.tokens, hyst.tokens);
    assert_eq!(eager.tokens, look.tokens);
    // Phase stickiness must reduce bitstream traffic...
    assert!(
        hyst.swaps < eager.swaps,
        "hysteresis {} swaps vs eager {}",
        hyst.swaps,
        eager.swaps
    );
    // ...and the acceptance bar: a non-eager policy beats the paper's
    // eager flow on decode throughput under mixed traffic at 16k context.
    let best = hyst.decode_tps.max(look.decode_tps);
    assert!(
        best > eager.decode_tps,
        "neither hysteresis ({:.3} t/s) nor lookahead ({:.3} t/s) beat eager ({:.3} t/s)",
        hyst.decode_tps,
        look.decode_tps,
        eager.decode_tps
    );

    // Host wall-clock cost of the simulation itself (not KV260 time).
    if !smoke {
        bench::section("simulation wall-clock");
        let s = bench::run("mixed 16k trace, all three policies", 1, 3, || {
            for p in [
                SwapPolicy::Eager,
                SwapPolicy::hysteresis_default(),
                SwapPolicy::lookahead_default(),
            ] {
                std::hint::black_box(run_policy(p, mixed_trace()));
            }
        });
        println!("{s}");
    }

    let report = Value::Obj(vec![
        ("bench".into(), Value::Str("swap_policy".into())),
        ("model".into(), Value::Str(LONG_CTX_16K.name.into())),
        ("peak_context".into(), Value::Num((LONG_PROMPT + LONG_GEN) as f64)),
        ("n_requests".into(), Value::Num((N_LONG + N_SHORT) as f64)),
        ("gen_tokens_total".into(), Value::Num(total_tokens as f64)),
        (
            "policies".into(),
            Value::Obj(runs.iter().map(|r| (r.name.to_string(), run_json(r))).collect()),
        ),
        (
            "hysteresis_over_eager_decode_tps".into(),
            Value::Num(hyst.decode_tps / eager.decode_tps.max(1e-12)),
        ),
        (
            "lookahead_over_eager_decode_tps".into(),
            Value::Num(look.decode_tps / eager.decode_tps.max(1e-12)),
        ),
        // The two quantities the bench asserts on (and the baseline
        // hard-gates): the best non-eager policy's throughput ratio and
        // the swap saving. Keep these in lockstep with the asserts above.
        (
            "best_over_eager_decode_tps".into(),
            Value::Num(best / eager.decode_tps.max(1e-12)),
        ),
        (
            "eager_minus_hysteresis_swaps".into(),
            Value::Num(eager.swaps as f64 - hyst.swaps as f64),
        ),
    ]);
    match bench::write_json_report(out, &report) {
        Ok(p) => println!("\nwrote {p}"),
        Err(e) => eprintln!("\ncould not write {out}: {e}"),
    }
}
