//! Long-context study — the regime where PD-Swap's gains grow (Fig. 6's
//! "larger gains at longer context lengths") plus the ablation grid the
//! paper implies but doesn't print:
//!
//! * PD-Swap (DPR, 2K+2V ports, overlap)        — the full system
//! * PD-Swap minus the port remap               — isolates §3.2.3
//! * PD-Swap minus overlap                      — isolates §3.4
//! * static baseline                            — isolates DPR itself
//!
//! ```bash
//! cargo run --release --example long_context [-- --lengths 64,256,1024,2048 --gen 64]
//! ```

use anyhow::Result;
use pd_swap::coordinator::{Request, SimServer, SimServerConfig};
use pd_swap::engines::{AcceleratorDesign, PhaseModel};
use pd_swap::fpga::KV260;
use pd_swap::model::BITNET_0_73B;
use pd_swap::util::cli::Args;
use pd_swap::util::table::{fnum, Table};

fn main() -> Result<()> {
    let args = Args::from_env();
    let lengths = args.get_usize_list("lengths", &[64, 256, 512, 1024, 1536, 2048]);
    let gen = args.get_usize("gen", 64);
    let shape = BITNET_0_73B;

    // --- ablation variants -----------------------------------------------
    let pd = AcceleratorDesign::pd_swap();
    let mut pd_no_ports = pd.clone();
    pd_no_ports.decode_attn.kv_optimized_ports = false;
    pd_no_ports.name = "PD-Swap w/o 2K+2V".into();
    let tellme = AcceleratorDesign::tellme_static();

    println!("== long-context decode throughput (tokens/s) ==");
    let mut t = Table::new(vec![
        "L", "PD-Swap", "w/o port remap", "static (TeLLMe)", "full vs static",
    ])
    .right_align(&[0, 1, 2, 3, 4]);
    let m_pd = PhaseModel::new(pd.clone(), KV260.clone());
    let m_np = PhaseModel::new(pd_no_ports, KV260.clone());
    let m_te = PhaseModel::new(tellme, KV260.clone());
    for &l in &lengths {
        let a = m_pd.decode_throughput(&shape, l);
        let b = m_np.decode_throughput(&shape, l);
        let c = m_te.decode_throughput(&shape, l);
        t.row(vec![
            l.to_string(),
            fnum(a),
            fnum(b),
            fnum(c),
            format!("{:.2}x", a / c),
        ]);
    }
    t.print();

    // --- end-to-end request latency with/without overlap ------------------
    println!("\n== end-to-end single-request latency (prefill + swap + {gen} tokens) ==");
    let mut t2 = Table::new(vec![
        "prompt L", "PD-Swap e2e (s)", "no-overlap e2e (s)", "static e2e (s)", "exposed swap (ms)",
    ])
    .right_align(&[0, 1, 2, 3, 4]);
    for &l in &lengths {
        let run = |mut cfg: SimServerConfig| -> Result<(f64, f64)> {
            cfg.shape = shape;
            let mut s = SimServer::new(cfg)?;
            // Clamp so the generation fits the KV-cache capacity.
            let prompt = l.min(shape.max_seq - gen);
            s.run(vec![Request::synthetic(0, prompt, gen, 0.0)])?;
            Ok((s.metrics.e2e.mean(), s.metrics.reconfig_exposed.mean()))
        };
        let full = run(SimServerConfig::pd_swap(shape, KV260.clone()))?;
        let mut no_ov = SimServerConfig::pd_swap(shape, KV260.clone());
        no_ov.overlap = false;
        let no_ov = run(no_ov)?;
        let stat = run(SimServerConfig::tellme_static(shape, KV260.clone()))?;
        t2.row(vec![
            l.to_string(),
            fnum(full.0),
            fnum(no_ov.0),
            fnum(stat.0),
            format!("{:.1} / {:.1}", full.1 * 1e3, no_ov.1 * 1e3),
        ]);
    }
    t2.print();
    println!(
        "\nreading: the port remap carries the long-context gain; overlap removes the \
         swap cost at short contexts; DPR itself buys the headroom for both."
    );
    Ok(())
}
