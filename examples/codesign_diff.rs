//! Codesign-report differ: compare two `codesign-report.json` artifacts'
//! per-trace winners and flag flips.
//!
//! The `pd-swap codesign` sweep is fully deterministic, so across two
//! commits a per-trace winner (design + policy + decode batch + KV pool)
//! changes ONLY when the model, the sweep axes, or an intended
//! performance characteristic changed. CI's bench-smoke job downloads the
//! previous successful run's `codesign-report` artifact and runs this
//! differ against the fresh report: an unexplained flip is a regression
//! signal that would otherwise hide inside a green build.
//!
//! ```text
//! cargo run --example codesign_diff -- --prev old.json --curr new.json [--warn]
//! ```
//!
//! Exit status: 0 when the winners agree (or `--warn` was passed — flips
//! are then emitted as GitHub `::warning::` annotations with a labeled
//! diff); 1 when winners flipped without `--warn`; 2 on unreadable input
//! (except that `--warn` downgrades an unreadable `--prev` to a skipped
//! diff — a corrupt previous artifact is an infra hiccup, not a signal).
//! Traces present in only one report are reported but never count as
//! flips (the axis legitimately changes when the sweep config does).

use std::process::ExitCode;

use pd_swap::dse::PoolVariant;
use pd_swap::util::bench::report_body;
use pd_swap::util::cli::Args;
use pd_swap::util::json::{parse, Value};

/// The identity of one winner cell, as compared across reports.
#[derive(Debug, PartialEq)]
struct Winner {
    design: String,
    policy: String,
    decode_batch: i64,
    pool: String,
}

impl Winner {
    fn from_cell(cell: &Value) -> Option<Winner> {
        Some(Winner {
            design: cell.get("design")?.as_str()?.to_string(),
            policy: cell.get("policy")?.as_str()?.to_string(),
            decode_batch: cell.get("decode_batch")?.as_f64()? as i64,
            // Older reports (pre-pool-axis) carry no pool column; treat
            // it as the default variant so adding the axis is not a flip.
            pool: cell
                .get("pool")
                .and_then(Value::as_str)
                .map(str::to_string)
                .unwrap_or_else(|| PoolVariant::paper_default().label()),
        })
    }

    fn label(&self) -> String {
        format!(
            "{} + {} @ B={} / {}",
            self.design, self.policy, self.decode_batch, self.pool
        )
    }
}

fn load(path: &str) -> Result<Value, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    parse(&text).map_err(|e| format!("{path}: {e:?}"))
}

/// Trace-name → winner map from a report.
fn winners(report: &Value) -> Vec<(String, Winner)> {
    let Some(Value::Obj(traces)) = report.get("traces") else {
        return Vec::new();
    };
    traces
        .iter()
        .filter_map(|(name, t)| {
            t.get("winner")
                .and_then(Winner::from_cell)
                .map(|w| (name.clone(), w))
        })
        .collect()
}

fn main() -> ExitCode {
    let args = Args::from_env();
    let Some(prev_path) = args.get("prev") else {
        eprintln!("usage: codesign_diff --prev FILE --curr FILE [--warn]");
        return ExitCode::from(2);
    };
    let Some(curr_path) = args.get("curr") else {
        eprintln!("usage: codesign_diff --prev FILE --curr FILE [--warn]");
        return ExitCode::from(2);
    };
    let warn_only = args.flag("warn");

    let curr = match load(curr_path) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("codesign_diff: {e}");
            return ExitCode::from(2);
        }
    };
    let prev = match load(prev_path) {
        Ok(p) => p,
        Err(e) if warn_only => {
            // Best-effort mode: a truncated/corrupt previous artifact is
            // an infra hiccup (interrupted upload, partial download), not
            // a regression signal — skip the diff instead of failing CI.
            println!("codesign_diff: previous report unreadable ({e}); skipping diff");
            return ExitCode::SUCCESS;
        }
        Err(e) => {
            eprintln!("codesign_diff: {e}");
            return ExitCode::from(2);
        }
    };

    // Accept both enveloped (schema_version / git_rev / config_hash) and
    // legacy report documents.
    let prev_winners = winners(report_body(&prev));
    let curr_winners = winners(report_body(&curr));
    if curr_winners.is_empty() {
        eprintln!("codesign_diff: no per-trace winners in {curr_path}");
        return ExitCode::from(2);
    }

    let mut flips = 0usize;
    for (trace, cw) in &curr_winners {
        match prev_winners.iter().find(|(t, _)| t == trace) {
            None => {
                println!("trace '{trace}': new in this report ({}) — not a flip", cw.label());
            }
            Some((_, pw)) if pw == cw => {
                println!("trace '{trace}': winner unchanged ({})", cw.label());
            }
            Some((_, pw)) => {
                flips += 1;
                let line = format!(
                    "trace '{trace}': winner FLIPPED: {} -> {}",
                    pw.label(),
                    cw.label()
                );
                if warn_only {
                    // GitHub annotation: visible in the job summary
                    // without failing the build (an intended model change
                    // legitimately flips winners).
                    println!("::warning title=codesign winner flip::{line}");
                } else {
                    println!("{line}");
                }
            }
        }
    }
    for (trace, pw) in &prev_winners {
        if !curr_winners.iter().any(|(t, _)| t == trace) {
            println!("trace '{trace}': dropped from this report (was {})", pw.label());
        }
    }

    if flips == 0 {
        println!("codesign_diff: no winner flips across {} traces", curr_winners.len());
        ExitCode::SUCCESS
    } else if warn_only {
        println!("codesign_diff: {flips} winner flip(s) — warning only (--warn)");
        ExitCode::SUCCESS
    } else {
        println!("codesign_diff: {flips} winner flip(s)");
        ExitCode::FAILURE
    }
}
