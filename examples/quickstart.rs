//! Quickstart: load the `tiny` artifacts, generate real tokens through the
//! PJRT runtime, and show the simulated-KV260 timing alongside.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example quickstart [-- --artifacts artifacts/tiny]
//! ```

use anyhow::Result;
use pd_swap::coordinator::{LiveServer, LiveServerConfig, Request};
use pd_swap::runtime::SamplerConfig;
use pd_swap::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let dir = args.get_or("artifacts", "artifacts/tiny");

    println!("== PD-Swap quickstart ==");
    println!("loading artifacts from {dir} (compiling HLO on the PJRT CPU client) ...");
    let mut server = LiveServer::new(LiveServerConfig {
        artifacts_dir: dir.into(),
        sampler: SamplerConfig::default(), // greedy
        seed: 0,
        simulate_fpga: true,
    })?;
    let cfg = server.engine.manifest().config.clone();
    println!(
        "model: {} — {} layers, d_model {}, {} heads, vocab {}, max_seq {}",
        cfg.name, cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.vocab, cfg.max_seq
    );
    println!("weights: {:.1} MB uploaded once\n", server.engine.weight_bytes as f64 / 1e6);

    // A few prompts of different lengths (token ids are synthetic — the
    // model is trained on nothing; what matters is that the *system*
    // produces deterministic, cross-checked generations).
    let prompts: Vec<Vec<i32>> = vec![
        (1..=5).collect(),
        (10..=40).collect(),
        (100..=163).collect(),
    ];

    for (i, prompt) in prompts.into_iter().enumerate() {
        let req = Request::with_tokens(i as u64, prompt.clone(), 16, 0.0);
        let out = server.serve(&req)?;
        println!("request {i}: prompt len {:3} -> {:?}", prompt.len(), out.outcome.generated);
        println!(
            "  host (PJRT CPU): ttft {:6.1} ms | decode {:5.1} tok/s",
            out.outcome.ttft * 1e3,
            1.0 / out.outcome.mean_tpot.max(1e-9)
        );
        if let (Some(st), Some(se)) = (out.sim_ttft, out.sim_e2e) {
            println!("  simulated KV260 (PD-Swap timing, this model shape): ttft {st:.3} s | e2e {se:.3} s");
        }
    }

    println!("\nhost metrics:\n{}", server.metrics.report());
    Ok(())
}
