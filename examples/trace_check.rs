//! Chrome-trace validator for CI: parse a `--trace-out` /
//! `--trace-winners` artifact and check its structural invariants —
//! every event carries the required fields, every duration is
//! non-negative, every B/E span is closed, and timestamps are monotone
//! non-decreasing per (pid, tid) track (the recorder's deterministic
//! emission order).
//!
//! ```text
//! cargo run --example trace_check -- --file trace.json [--require-decision]
//! ```
//!
//! `--require-decision` additionally demands at least one swap-policy
//! decision record (cat `"policy"`) — the bench-smoke job passes it for
//! the lookahead simulate run, where the policy must have weighed at
//! least one swap. Exit status: 0 valid, 1 invalid, 2 unreadable input.
//!
//! Fast-forwarded traces (the default since the analytic decode fold
//! landed) coalesce steady-state decode stretches into `decode-ff`
//! spans; the validator checks those carry well-formed `args.k` /
//! `args.step_s`, and the summary line reports how many folds the
//! trace contains so CI logs show the coalescing at a glance.

use std::process::ExitCode;

use pd_swap::telemetry::validate_chrome_trace;
use pd_swap::util::cli::Args;
use pd_swap::util::json::{parse, Value};

fn main() -> ExitCode {
    let args = Args::from_env();
    let Some(path) = args.get("file") else {
        eprintln!("usage: trace_check --file trace.json [--require-decision]");
        return ExitCode::from(2);
    };
    let doc = match std::fs::read_to_string(path)
        .map_err(|e| e.to_string())
        .and_then(|s| parse(&s).map_err(|e| format!("{e:?}")))
    {
        Ok(v) => v,
        Err(e) => {
            eprintln!("trace_check: {path}: {e}");
            return ExitCode::from(2);
        }
    };

    let checked = match validate_chrome_trace(&doc) {
        Ok(n) => n,
        Err(e) => {
            eprintln!("trace_check: {path}: INVALID: {e}");
            return ExitCode::FAILURE;
        }
    };

    let events = doc.get("traceEvents").and_then(Value::as_arr);
    let decisions = events
        .map(|evs| {
            evs.iter()
                .filter(|e| e.get("cat").and_then(Value::as_str) == Some("policy"))
                .count()
        })
        .unwrap_or(0);
    // Coalesced fast-forward spans and the token-steps they stand in for.
    let (ff_spans, ff_tokens) = events
        .map(|evs| {
            evs.iter()
                .filter(|e| e.get("name").and_then(Value::as_str) == Some("decode-ff"))
                .fold((0usize, 0u64), |(n, k), e| {
                    let steps = e
                        .get("args")
                        .and_then(|a| a.get("k"))
                        .and_then(Value::as_f64)
                        .unwrap_or(0.0) as u64;
                    (n + 1, k + steps)
                })
        })
        .unwrap_or((0, 0));
    if args.flag("require-decision") && decisions == 0 {
        eprintln!(
            "trace_check: {path}: INVALID: no swap-policy decision records \
             (expected at least one cat=\"policy\" instant)"
        );
        return ExitCode::FAILURE;
    }

    println!(
        "trace_check: {path}: OK — {checked} events validated, {decisions} policy decisions, \
         {ff_spans} coalesced decode-ff spans ({ff_tokens} folded token-steps)"
    );
    ExitCode::SUCCESS
}
