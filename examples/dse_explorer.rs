//! DSE explorer — walk the paper's §3.3 flow end to end:
//!
//! 1. grid-explore the design space for both hostings (DPR vs static),
//! 2. print the Pareto-ish top designs and the Eq. 6 winner,
//! 3. run the Fig. 4b automated implementation flow on an over-provisioned
//!    design and show the routability feedback loop shrinking it to fit.
//!
//! ```bash
//! cargo run --release --example dse_explorer [-- --l-long 2048 --alpha 0.7]
//! ```

use anyhow::Result;
use pd_swap::dse::{explore, implement_with_feedback, DseConfig};
use pd_swap::engines::{AcceleratorDesign, AttentionHosting, PhaseModel};
use pd_swap::fpga::KV260;
use pd_swap::model::BITNET_0_73B;
use pd_swap::util::cli::Args;
use pd_swap::util::table::{fnum, Table};

fn main() -> Result<()> {
    let args = Args::from_env();
    let shape = BITNET_0_73B;

    println!("== PD-Swap design space exploration (Eq. 6, α = {}) ==", 0.7);
    let mut results = Vec::new();
    for hosting in [AttentionHosting::Reconfigurable, AttentionHosting::StaticBoth] {
        let mut cfg = DseConfig::paper_default(shape, KV260.clone(), hosting);
        cfg.l_long = args.get_usize("l-long", cfg.l_long);
        cfg.l_short = args.get_usize("l-short", cfg.l_short);
        cfg.alpha = args.get_f64("alpha", cfg.alpha);
        let label = match hosting {
            AttentionHosting::Reconfigurable => "DPR (PD-Swap)",
            AttentionHosting::StaticBoth => "static (TeLLMe-class)",
        };
        println!(
            "\n--- {label}: exploring {} candidates ---",
            cfg.tlmm_grid.len() * cfg.prefill_grid.len() * cfg.decode_grid.len()
        );
        let res = explore(&cfg)?;
        println!("feasible: {} / {}", res.feasible, res.explored);

        let mut t = Table::new(vec![
            "design", "T_pre(768) s", "dec@2048 tok/s", "dec@128 tok/s", "objective",
        ])
        .right_align(&[1, 2, 3, 4]);
        for p in res.top.iter().take(5) {
            t.row(vec![
                p.design.name.clone(),
                fnum(p.t_pre),
                fnum(1.0 / p.t_dec_long),
                fnum(1.0 / p.t_dec_short),
                fnum(p.objective),
            ]);
        }
        t.print();
        results.push((label, res));
    }

    let dpr = &results[0].1.best;
    let stat = &results[1].1.best;
    println!(
        "\nDPR wins Eq. 6 by {:.1}% ({:.3} vs {:.3}) — the paper's headline ablation.",
        (stat.objective / dpr.objective - 1.0) * 100.0,
        dpr.objective,
        stat.objective
    );

    // --- Fig. 4b: automated implementation flow with routability feedback.
    println!("\n== automated implementation flow (Fig. 4b) ==");
    let mut over = AcceleratorDesign::pd_swap();
    over.prefill_attn.n_dsp = 650;
    over.decode_attn.n_dsp = 600;
    over.name = "over-provisioned".into();
    println!("starting from an over-provisioned design (pre 650 / dec 600 DSP):");
    let (fixed, log) = implement_with_feedback(&KV260, over, 50, 20);
    for it in &log {
        match &it.outcome {
            Ok(util) => println!(
                "  attempt {}: {} -> P&R OK (peak util {:.1}%)",
                it.attempt,
                it.design_name,
                util * 100.0
            ),
            Err(e) => println!("  attempt {}: {} -> {}", it.attempt, it.design_name, e),
        }
    }
    let fixed = fixed.expect("flow converges");
    let model = PhaseModel::new(fixed.clone(), KV260.clone());
    println!(
        "converged: pre {} / dec {} DSP; decode@2048 = {:.1} tok/s, TTFT@768 = {:.2} s",
        fixed.prefill_attn.n_dsp,
        fixed.decode_attn.n_dsp,
        model.decode_throughput(&shape, 2048),
        model.prefill(&shape, 768).total
    );
    Ok(())
}
