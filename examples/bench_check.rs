//! Bench-regression gate: compare live `BENCH_*.json` reports against
//! the baselines committed under `benches/baselines/`.
//!
//! CI's `bench-smoke` job runs the benches in reduced-iteration mode,
//! then runs this checker; any non-advisory gate outside its tolerance
//! band fails the build. All gated values are deterministic virtual-clock
//! simulation numbers, so the comparison is exact across machines.
//!
//! ```bash
//! cargo bench --bench kvpool_serving -- --smoke
//! cargo bench --bench swap_policy   -- --smoke
//! cargo run --example bench_check
//! # after an intentional perf change (or to calibrate estimates):
//! cargo run --example bench_check -- --bless && git add benches/baselines
//! ```
//!
//! Flags: `--baseline-dir DIR` (default `benches/baselines`), `--dir DIR`
//! where the live reports live (default `.`), `--bless` to rewrite the
//! baselines' expected values from the live reports.

use std::path::Path;
use std::process::ExitCode;

use pd_swap::util::bench::{bless_baseline, compare_reports, parse_gates, report_body};
use pd_swap::util::cli::Args;
use pd_swap::util::json;

fn main() -> ExitCode {
    let args = Args::from_env();
    let baseline_dir = args.get_or("baseline-dir", "benches/baselines");
    let report_dir = args.get_or("dir", ".");
    let bless = args.flag("bless");

    let entries = match std::fs::read_dir(baseline_dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("cannot read baseline dir {baseline_dir}: {e}");
            return ExitCode::FAILURE;
        }
    };
    let mut names: Vec<String> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
        .collect();
    names.sort();
    if names.is_empty() {
        eprintln!("no BENCH_*.json baselines under {baseline_dir}");
        return ExitCode::FAILURE;
    }

    let mut failed = false;
    for name in &names {
        let base_path = Path::new(baseline_dir).join(name);
        let cur_path = Path::new(report_dir).join(name);
        let baseline = match std::fs::read_to_string(&base_path)
            .map_err(|e| e.to_string())
            .and_then(|s| json::parse(&s).map_err(|e| e.to_string()))
        {
            Ok(v) => v,
            Err(e) => {
                println!("FAIL {name}: unreadable baseline: {e}");
                failed = true;
                continue;
            }
        };
        let current = match std::fs::read_to_string(&cur_path)
            .map_err(|e| e.to_string())
            .and_then(|s| json::parse(&s).map_err(|e| e.to_string()))
        {
            Ok(v) => v,
            Err(e) => {
                println!(
                    "FAIL {name}: missing/unreadable live report at {}: {e} (run the bench first)",
                    cur_path.display()
                );
                failed = true;
                continue;
            }
        };

        // Live reports may carry the versioned envelope (schema_version /
        // git_rev / config_hash); gates address the body either way.
        // Baselines are hand-maintained and stay legacy.
        let current = report_body(&current);

        if bless {
            let blessed = bless_baseline(&baseline, current);
            if let Err(e) = std::fs::write(&base_path, blessed.to_pretty()) {
                println!("FAIL {name}: cannot write blessed baseline: {e}");
                failed = true;
                continue;
            }
            println!(
                "BLESSED {name}: {} gate values rewritten from the live report",
                parse_gates(&blessed).len()
            );
            continue;
        }

        let cmp = compare_reports(&baseline, current);
        let failures = cmp.failures();
        for r in &cmp.results {
            let status = if !r.regressed {
                "ok  "
            } else if r.gate.advisory {
                "ADV "
            } else {
                "FAIL"
            };
            let dir = if r.gate.higher_is_better { "min" } else { "max" };
            match r.current {
                Some(c) => println!(
                    "  {status} {:<48} {dir} {:<12.4} got {:.4}",
                    r.gate.path, r.gate.value, c
                ),
                None => println!(
                    "  {status} {:<48} {dir} {:<12.4} got <missing>",
                    r.gate.path, r.gate.value
                ),
            }
        }
        if failures.is_empty() {
            println!("PASS {name}: {} gates checked", cmp.results.len());
        } else {
            println!(
                "FAIL {name}: {} of {} gates regressed beyond tolerance \
                 (if intentional: `cargo run --example bench_check -- --bless`)",
                failures.len(),
                cmp.results.len()
            );
            failed = true;
        }
    }

    if failed {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}
