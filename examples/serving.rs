//! End-to-end serving driver — the deliverable (b)/(e2e) workload: load
//! the ~103M-parameter model, serve a batch of requests with REAL PJRT
//! execution (every token comes out of the compiled HLO artifacts), and
//! report latency/throughput on both clocks:
//!
//! * host wall clock (PJRT CPU — this is the functional substrate, not a
//!   KV260 measurement), and
//! * the simulated KV260 running PD-Swap on the paper's BitNet 0.73B
//!   timing model, driven in lockstep with the same request trace.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example serving -- --requests 6 --gen 24
//! # smaller/faster: --artifacts artifacts/tiny
//! ```
//!
//! The run recorded in EXPERIMENTS.md §E2E used the default arguments.

use anyhow::Result;
use pd_swap::coordinator::{
    generate_workload, EventServer, EventServerConfig, LiveServer, LiveServerConfig, Request,
    WorkloadConfig,
};
use pd_swap::fpga::KV260;
use pd_swap::model::BITNET_0_73B;
use pd_swap::reconfig::SwapPolicy;
use pd_swap::runtime::{SamplerConfig, SamplingMode};
use pd_swap::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let dir = args.get_or("artifacts", "artifacts/e2e-100m");
    let n_requests = args.get_usize("requests", 6);
    let gen = args.get_usize("gen", 24);
    let seed = args.get_u64("seed", 0);

    println!("== PD-Swap end-to-end serving driver ==");
    println!("loading + compiling {dir} ...");
    let t0 = std::time::Instant::now();
    let mut server = LiveServer::new(LiveServerConfig {
        artifacts_dir: dir.into(),
        sampler: SamplerConfig { mode: SamplingMode::TopK { k: 40, temperature: 0.8 } },
        seed,
        simulate_fpga: true,
    })?;
    println!("engine ready in {:.1} s", t0.elapsed().as_secs_f64());

    let m = server.engine.manifest().config.clone();
    println!(
        "model {}: {} layers / d_model {} / {} heads / vocab {} — {} params, {:.1} MB packed weights",
        m.name,
        m.n_layers,
        m.d_model,
        m.n_heads,
        m.vocab,
        server.engine.manifest().n_params,
        server.engine.weight_bytes as f64 / 1e6
    );

    let wl = generate_workload(&WorkloadConfig {
        n_requests,
        arrival_rate: 0.2,
        prompt_len: (16, *m.prefill_buckets.last().unwrap()),
        gen_len: (gen / 2, gen),
        seed,
        vocab: m.vocab,
    });
    println!("\nserving {n_requests} requests (Poisson arrivals, log-uniform prompts) ...");
    let outcomes = server.run(&wl)?;

    println!("\n per-request results:");
    for o in &outcomes {
        println!(
            "  req {:2} prompt {:4} gen {:3} | host ttft {:8.1} ms tpot {:7.1} ms | sim-KV260 ttft {:7.3} s e2e {:7.3} s",
            o.outcome.id,
            o.outcome.prompt_len,
            o.outcome.generated.len(),
            o.outcome.ttft * 1e3,
            o.outcome.mean_tpot * 1e3,
            o.sim_ttft.unwrap_or(0.0),
            o.sim_e2e.unwrap_or(0.0),
        );
    }

    println!("\nhost (PJRT CPU) metrics:\n{}", server.metrics.report());
    println!(
        "  host decode throughput: {:.2} tok/s",
        server.metrics.decode_throughput()
    );
    println!(
        "\nsimulated KV260 (PD-Swap timing model, this model shape) for the same traces:\n{}",
        server.sim_metrics.report()
    );
    println!(
        "  simulated decode throughput: {:.2} tok/s (this shape; the paper\'s 27.8 is BitNet 0.73B — see `pd-swap eval fig6`)",
        server.sim_metrics.decode_throughput()
    );

    // Swap-policy comparison on the event-driven core: replay the same
    // arrival trace (BitNet 0.73B timing model) under each DPR
    // swap-scheduling policy to show what continuous serving would do
    // with this traffic on the real edge part.
    println!("\nswap-policy comparison (event-driven sim, BitNet 0.73B timing):");
    println!(
        "  {:<12} {:>6} {:>12} {:>12} {:>12}",
        "policy", "swaps", "tok/s", "ttft p95 s", "makespan s"
    );
    for policy in [
        SwapPolicy::Eager,
        SwapPolicy::hysteresis_default(),
        SwapPolicy::lookahead_default(),
    ] {
        let sim_wl: Vec<Request> = wl
            .iter()
            .map(|r| {
                Request::synthetic(
                    r.id,
                    r.prompt_len.min(BITNET_0_73B.max_seq / 2),
                    r.max_new_tokens,
                    r.arrival,
                )
            })
            .collect();
        let mut sim = EventServer::new(EventServerConfig::pd_swap(
            BITNET_0_73B,
            KV260.clone(),
            policy,
        ))?;
        sim.run(sim_wl)?;
        println!(
            "  {:<12} {:>6} {:>12.2} {:>12.2} {:>12.1}",
            policy.name(),
            sim.metrics.reconfigurations.get(),
            sim.metrics.tokens_generated.get() as f64 / sim.clock().max(1e-9),
            sim.metrics.ttft.quantile(0.95),
            sim.clock(),
        );
    }
    Ok(())
}
